//! The rlite evaluator.
//!
//! Eager, environment-based evaluation with:
//!
//! - special forms (unevaluated-argument builtins) — the hook that makes
//!   `futurize()` possible: it receives the raw [`Expr`] of its first
//!   argument, exactly like R's `substitute()` capture;
//! - a condition-handler stack (suppressors, calling handlers, exiting
//!   `tryCatch` handlers, and capture collectors used on workers);
//! - a capturable stdout sink stack;
//! - an RNG context (MRG32k3a) with use-tracking for the paper's
//!   "RNG used without `seed = TRUE`" misuse warning.

use std::cell::RefCell;
use std::rc::Rc;

use super::ast::{Arg, Expr};
use super::builtins::{self, Args, BuiltinFn};
use super::conditions::{CaptureLog, RCondition, Severity};
use super::deparse::deparse;
use super::env::{self, Env, EnvRef};
use super::intern::{sym_dots, Symbol};
use super::value::{RClosure, RList, RVal};
use crate::future_core::SessionState;
use crate::rng::RngStream;

/// Non-local control flow.
#[derive(Clone, Debug)]
pub enum Signal {
    /// `stop()` or a runtime error.
    Error(RCondition),
    /// An exiting condition handler (tryCatch) matched: unwind to frame `id`.
    Unwind { cond: RCondition, id: u64 },
    Break,
    Next,
    Return(RVal),
}

impl Signal {
    pub fn error(msg: impl Into<String>) -> Signal {
        Signal::Error(RCondition::error_cond(msg))
    }
}

pub type EvalResult = Result<RVal, Signal>;

/// Where `cat()`/`print()` output goes.
pub enum OutSink {
    /// Real process stdout.
    Real,
    /// Captured into a buffer (worker tasks, `capture.output`-style tests).
    Capture(Rc<RefCell<String>>),
    /// Discarded.
    Sink,
}

/// A frame on the condition-handler stack.
pub enum HandlerFrame {
    /// `suppressMessages()` / `suppressWarnings()`: muffle matching classes.
    Suppress { classes: Vec<String> },
    /// Worker-side capture: collect (and muffle) matching conditions so the
    /// parent can relay them as-is.
    Collect { classes: Vec<String>, sink: Rc<RefCell<Vec<RCondition>>> },
    /// `withCallingHandlers(class = f)`: invoke `f` in place, continue.
    Calling { class: String, func: RVal },
    /// A Rust-side calling handler (used by backends to stream progress
    /// conditions to the parent near-live, paper §4.10).
    Native {
        class: String,
        #[allow(clippy::type_complexity)]
        hook: Rc<RefCell<dyn FnMut(&RCondition)>>,
    },
    /// `tryCatch(class = f)`: unwind to the tryCatch frame with `id`.
    Exiting { classes: Vec<String>, id: u64 },
}

/// Interpreter configuration.
#[derive(Clone, Debug)]
pub struct InterpConfig {
    /// Multiplier applied to `Sys.sleep()` durations. The paper's examples
    /// use 1-second tasks; benches scale this down to keep runs fast while
    /// preserving the *shape* of the timing results.
    pub time_scale: f64,
    /// Upper bound on loop iterations (runaway-guard for property tests).
    pub max_iter: usize,
}

impl Default for InterpConfig {
    fn default() -> Self {
        InterpConfig { time_scale: 1.0, max_iter: 50_000_000 }
    }
}

/// The rlite interpreter. One per session / per worker task.
pub struct Interp {
    pub global: EnvRef,
    pub out: Vec<OutSink>,
    pub handlers: Vec<HandlerFrame>,
    pub config: InterpConfig,
    /// Current RNG stream (L'Ecuyer MRG32k3a).
    pub rng: RngStream,
    /// Set when any RNG-consuming builtin runs (misuse detection).
    pub rng_used: bool,
    /// futurize() global toggle (paper §2.1 "Global disable/enable").
    pub futurize_enabled: bool,
    /// future-ecosystem state: plan stack, backend cache, task trace.
    pub session: SessionState,
    /// Monotone counter for tryCatch frame ids.
    next_frame_id: u64,
}

impl Default for Interp {
    fn default() -> Self {
        Self::new()
    }
}

impl Interp {
    pub fn new() -> Self {
        let global = Env::new_ref();
        // Base constants.
        env::define(&global, "pi", RVal::scalar_dbl(std::f64::consts::PI));
        env::define(&global, "T", RVal::scalar_bool(true));
        env::define(&global, "F", RVal::scalar_bool(false));
        let letters: Vec<String> = ('a'..='z').map(|c| c.to_string()).collect();
        env::define(
            &global,
            "LETTERS",
            RVal::chr(letters.iter().map(|s| s.to_uppercase()).collect()),
        );
        env::define(&global, "letters", RVal::chr(letters));
        Interp {
            global,
            out: vec![OutSink::Real],
            handlers: Vec::new(),
            config: InterpConfig::default(),
            rng: RngStream::from_seed(42),
            rng_used: false,
            futurize_enabled: true,
            session: SessionState::default(),
            next_frame_id: 0,
        }
    }

    pub fn with_config(config: InterpConfig) -> Self {
        let mut i = Self::new();
        i.config = config;
        i
    }

    pub fn fresh_frame_id(&mut self) -> u64 {
        self.next_frame_id += 1;
        self.next_frame_id
    }

    // ---- output ---------------------------------------------------------

    /// Write to the innermost stdout sink.
    pub fn write_out(&mut self, s: &str) {
        match self.out.last().unwrap_or(&OutSink::Real) {
            OutSink::Real => print!("{s}"),
            OutSink::Capture(buf) => buf.borrow_mut().push_str(s),
            OutSink::Sink => {}
        }
    }

    /// Run `f` with stdout captured; returns (result, captured-text).
    pub fn capture_stdout<T>(&mut self, f: impl FnOnce(&mut Interp) -> T) -> (T, String) {
        let buf = Rc::new(RefCell::new(String::new()));
        self.out.push(OutSink::Capture(buf.clone()));
        let r = f(self);
        self.out.pop();
        let text = buf.borrow().clone();
        (r, text)
    }

    // ---- conditions -------------------------------------------------------

    /// Signal a non-error condition through the handler stack. Returns
    /// `Err(Signal::Unwind ...)` if an exiting (tryCatch) handler matched.
    pub fn signal_condition(&mut self, cond: RCondition) -> Result<(), Signal> {
        // Walk innermost-out. Calling handlers run in place; the first
        // Suppress/Collect/Exiting match decides the disposition.
        // Native hooks (infrastructure streaming/display) observe every
        // matching condition no matter where they sit on the stack; the
        // R-visible handlers keep innermost-first, first-match-muffles
        // semantics.
        let mut native: Vec<Rc<RefCell<dyn FnMut(&RCondition)>>> = Vec::new();
        for frame in self.handlers.iter() {
            if let HandlerFrame::Native { class, hook } = frame {
                if cond.inherits(class) {
                    native.push(hook.clone());
                }
            }
        }
        let mut calling: Vec<RVal> = Vec::new();
        let mut disposition: Option<Result<(), Signal>> = None;
        for frame in self.handlers.iter().rev() {
            match frame {
                HandlerFrame::Calling { class, func } if cond.inherits(class) => {
                    calling.push(func.clone());
                }
                HandlerFrame::Suppress { classes } if classes.iter().any(|c| cond.inherits(c)) => {
                    disposition = Some(Ok(()));
                    break;
                }
                HandlerFrame::Collect { classes, sink }
                    if classes.iter().any(|c| cond.inherits(c)) =>
                {
                    sink.borrow_mut().push(cond.clone());
                    disposition = Some(Ok(()));
                    break;
                }
                HandlerFrame::Exiting { classes, id }
                    if classes.iter().any(|c| cond.inherits(c)) =>
                {
                    disposition = Some(Err(Signal::Unwind { cond: cond.clone(), id: *id }));
                    break;
                }
                _ => {}
            }
        }
        // Native hooks first (progress streaming), then calling handlers.
        for h in native {
            (h.borrow_mut())(&cond);
        }
        // Invoke calling handlers (outermost-last order is fine here).
        for f in calling {
            let arg = RVal::Cond(Box::new(cond.clone()));
            let genv = self.global.clone();
            let _ = self.call_function(&f, vec![(None, arg)], &genv)?;
        }
        match disposition {
            Some(d) => d,
            None => {
                // Unhandled: default side effect.
                match cond.severity {
                    Severity::Message => {
                        let msg = cond.message.clone();
                        self.write_err(&msg);
                    }
                    Severity::Warning => {
                        let msg = format!("Warning message:\n{}\n", cond.message);
                        self.write_err(&msg);
                    }
                    Severity::Custom => { /* inert */ }
                    Severity::Error => unreachable!("errors do not pass through signal_condition"),
                }
                Ok(())
            }
        }
    }

    /// stderr-ish output (messages/warnings). Captured together with
    /// stdout when a Capture sink is active, since the future framework
    /// relays both.
    pub fn write_err(&mut self, s: &str) {
        match self.out.last().unwrap_or(&OutSink::Real) {
            OutSink::Real => eprint!("{s}"),
            OutSink::Capture(buf) => buf.borrow_mut().push_str(s),
            OutSink::Sink => {}
        }
    }

    /// Evaluate an expression while capturing stdout + all non-error
    /// conditions (the worker-side half of "relay as-is", §4.9).
    pub fn eval_captured(&mut self, expr: &Expr, env: &EnvRef) -> (EvalResult, CaptureLog) {
        let sink = Rc::new(RefCell::new(Vec::new()));
        let buf = Rc::new(RefCell::new(String::new()));
        self.handlers.push(HandlerFrame::Collect {
            classes: vec!["condition".into()],
            sink: sink.clone(),
        });
        self.out.push(OutSink::Capture(buf.clone()));
        let rng_before = self.rng_used;
        self.rng_used = false;
        let r = self.eval(expr, env);
        let rng_used = self.rng_used;
        self.rng_used = rng_before || rng_used;
        self.out.pop();
        self.handlers.pop();
        let log = CaptureLog {
            stdout: buf.borrow().clone(),
            conditions: sink.borrow().clone(),
            rng_used,
        };
        (r, log)
    }

    /// Relay a worker capture log in this (parent) interpreter: stdout is
    /// re-emitted, conditions are re-signaled so parent handlers
    /// (`suppressMessages()`, `tryCatch`, progress collectors) see them.
    pub fn relay(&mut self, log: &CaptureLog) -> Result<(), Signal> {
        if !log.stdout.is_empty() {
            let s = log.stdout.clone();
            self.write_out(&s);
        }
        for cond in &log.conditions {
            self.signal_condition(cond.clone())?;
        }
        Ok(())
    }

    // ---- program evaluation ----------------------------------------------

    pub fn eval_program(&mut self, src: &str) -> Result<RVal, Signal> {
        let exprs = super::parse_program(src).map_err(Signal::error)?;
        let genv = self.global.clone();
        let mut last = RVal::Null;
        for e in &exprs {
            last = self.eval(e, &genv)?;
        }
        Ok(last)
    }

    pub fn eval(&mut self, expr: &Expr, env: &EnvRef) -> EvalResult {
        match expr {
            Expr::Null => Ok(RVal::Null),
            Expr::Bool(b) => Ok(RVal::scalar_bool(*b)),
            Expr::Int(v) => Ok(RVal::scalar_int(*v)),
            Expr::Num(v) => Ok(RVal::scalar_dbl(*v)),
            Expr::Str(s) => Ok(RVal::scalar_str(s.clone())),
            Expr::Missing => Ok(RVal::Null),
            Expr::Dots => env::lookup_sym(env, sym_dots())
                .ok_or_else(|| Signal::error("'...' used out of context")),
            Expr::Sym(name) => env::lookup_sym(env, *name)
                .or_else(|| name.builtin_id().map(RVal::Builtin))
                .ok_or_else(|| Signal::error(format!("object '{name}' not found"))),
            Expr::Ns { pkg, name } => builtins::lookup_builtin_ns(pkg, name)
                .map(|d| RVal::Builtin(d.id))
                .ok_or_else(|| {
                    Signal::error(format!("object '{name}' not found in namespace '{pkg}'"))
                }),
            Expr::Function { params, body } => Ok(RVal::Closure(Rc::new(RClosure {
                params: params.clone(),
                body: (**body).clone(),
                env: env.clone(),
            }))),
            Expr::Block(stmts) => {
                let mut last = RVal::Null;
                for s in stmts {
                    last = self.eval(s, env)?;
                }
                Ok(last)
            }
            Expr::If { cond, then, els } => {
                let c = self.eval(cond, env)?.as_bool().map_err(Signal::error)?;
                if c {
                    self.eval(then, env)
                } else if let Some(e) = els {
                    self.eval(e, env)
                } else {
                    Ok(RVal::Null)
                }
            }
            Expr::For { var, seq, body } => {
                let seqv = self.eval(seq, env)?;
                for item in seqv.iter_elements() {
                    env::define_sym(env, *var, item);
                    match self.eval(body, env) {
                        Ok(_) => {}
                        Err(Signal::Break) => break,
                        Err(Signal::Next) => continue,
                        Err(e) => return Err(e),
                    }
                }
                Ok(RVal::Null)
            }
            Expr::While { cond, body } => {
                let mut iters = 0usize;
                loop {
                    let c = self.eval(cond, env)?.as_bool().map_err(Signal::error)?;
                    if !c {
                        break;
                    }
                    iters += 1;
                    if iters > self.config.max_iter {
                        return Err(Signal::error("while loop exceeded max_iter"));
                    }
                    match self.eval(body, env) {
                        Ok(_) => {}
                        Err(Signal::Break) => break,
                        Err(Signal::Next) => continue,
                        Err(e) => return Err(e),
                    }
                }
                Ok(RVal::Null)
            }
            Expr::Break => Err(Signal::Break),
            Expr::Next => Err(Signal::Next),
            Expr::Assign { target, value } => {
                let v = self.eval(value, env)?;
                self.assign(target, v.clone(), env)?;
                Ok(v)
            }
            Expr::SuperAssign { target, value } => {
                let v = self.eval(value, env)?;
                match target.as_ref() {
                    Expr::Sym(name) => {
                        // Find the nearest enclosing frame (excluding the
                        // current one) that binds `name`; else global.
                        let sym = *name;
                        let start = env.borrow().parent.clone();
                        let mut cur = start;
                        let mut placed = false;
                        while let Some(e) = cur {
                            if e.borrow().vars.contains(sym) {
                                e.borrow_mut().vars.insert(sym, v.clone());
                                placed = true;
                                break;
                            }
                            let parent = e.borrow().parent.clone();
                            cur = parent;
                        }
                        if !placed {
                            env::define_sym(&self.global, sym, v.clone());
                        }
                        Ok(v)
                    }
                    other => Err(Signal::error(format!(
                        "invalid <<- target: {}",
                        deparse(other)
                    ))),
                }
            }
            Expr::Index { obj, args, double } => {
                let o = self.eval(obj, env)?;
                let idx: Vec<RVal> = args
                    .iter()
                    .map(|a| self.eval(&a.value, env))
                    .collect::<Result<_, _>>()?;
                index_get(&o, &idx, *double).map_err(Signal::error)
            }
            Expr::Dollar { obj, name } => {
                let o = self.eval(obj, env)?;
                match &o {
                    RVal::List(l) => Ok(l.get(name).cloned().unwrap_or(RVal::Null)),
                    RVal::Env(e) => Ok(env::lookup(e, name).unwrap_or(RVal::Null)),
                    other => {
                        Err(Signal::error(format!("$ operator invalid for {}", other.class())))
                    }
                }
            }
            Expr::Call { func, args } => self.eval_call(expr, func, args, env),
        }
    }

    fn eval_call(&mut self, call: &Expr, func: &Expr, args: &[Arg], env: &EnvRef) -> EvalResult {
        // Resolve callee without evaluating arguments yet: special forms
        // receive raw expressions.
        let callee: RVal = match func {
            Expr::Sym(name) => match env::lookup_sym(env, *name) {
                Some(v) => v,
                None => match name.builtin_id() {
                    Some(id) => RVal::Builtin(id),
                    None => {
                        return Err(Signal::Error(
                            RCondition::error_cond(format!("could not find function \"{name}\""))
                                .with_call(deparse(call)),
                        ))
                    }
                },
            },
            Expr::Ns { pkg, name } => match builtins::lookup_builtin_ns(pkg, name) {
                Some(d) => RVal::Builtin(d.id),
                None => {
                    return Err(Signal::error(format!(
                        "could not find function \"{pkg}::{name}\""
                    )))
                }
            },
            other => self.eval(other, env)?,
        };

        if let RVal::Builtin(id) = &callee {
            let def = builtins::builtin_by_id(*id)
                .ok_or_else(|| Signal::error(format!("unknown builtin #{id}")))?;
            match &def.f {
                BuiltinFn::Special(f) => return f(self, args, env),
                BuiltinFn::Normal(f) => {
                    let vals = self.eval_args(args, env)?;
                    let r = f(self, Args::new(vals), env);
                    // Attach call text to otherwise-anonymous errors.
                    return r.map_err(|sig| match sig {
                        Signal::Error(mut c) if c.call.is_none() => {
                            c.call = Some(deparse(call));
                            Signal::Error(c)
                        }
                        other => other,
                    });
                }
            }
        }

        let vals = self.eval_args(args, env)?;
        self.call_function(&callee, vals, env).map_err(|sig| match sig {
            Signal::Error(mut c) if c.call.is_none() => {
                c.call = Some(deparse(call));
                Signal::Error(c)
            }
            other => other,
        })
    }

    /// Evaluate an argument list, splicing `...`.
    pub fn eval_args(
        &mut self,
        args: &[Arg],
        env: &EnvRef,
    ) -> Result<Vec<(Option<String>, RVal)>, Signal> {
        let mut out = Vec::with_capacity(args.len());
        for a in args {
            if matches!(a.value, Expr::Dots) {
                if let Some(RVal::List(l)) = env::lookup_sym(env, sym_dots()) {
                    let names = l.names.clone();
                    for (i, v) in l.vals.into_iter().enumerate() {
                        let nm = names
                            .as_ref()
                            .and_then(|ns| ns.get(i))
                            .filter(|s| !s.is_empty())
                            .cloned();
                        out.push((nm, v));
                    }
                } // absent `...` splices nothing
            } else if matches!(a.value, Expr::Missing) {
                out.push((a.name.clone(), RVal::Null));
            } else {
                let v = self.eval(&a.value, env)?;
                out.push((a.name.clone(), v));
            }
        }
        Ok(out)
    }

    /// Call a function value with already-evaluated arguments.
    pub fn call_function(
        &mut self,
        f: &RVal,
        args: Vec<(Option<String>, RVal)>,
        env: &EnvRef,
    ) -> EvalResult {
        match f {
            RVal::Closure(c) => self.call_closure(c, args),
            RVal::Builtin(id) => {
                let def = builtins::builtin_by_id(*id)
                    .ok_or_else(|| Signal::error(format!("unknown builtin #{id}")))?;
                match &def.f {
                    BuiltinFn::Normal(func) => func(self, Args::new(args), env),
                    BuiltinFn::Special(_) => Err(Signal::error(format!(
                        "special form '{}' cannot be called indirectly",
                        def.name
                    ))),
                }
            }
            other => {
                Err(Signal::error(format!("attempt to apply non-function ({})", other.class())))
            }
        }
    }

    pub fn call_closure(
        &mut self,
        c: &RClosure,
        mut args: Vec<(Option<String>, RVal)>,
    ) -> EvalResult {
        let fenv = Env::child_of(&c.env);
        self.call_closure_in(c, &mut args, &fenv)
    }

    /// Call `c` with its frame environment provided by the caller. The
    /// frame must be an (empty) child of `c.env`; the per-element map
    /// loop reuses one frame across elements instead of allocating an
    /// `Rc<RefCell<..>>` per call. Arguments are *drained* out of
    /// `args` (the vector is left empty with its capacity intact), so a
    /// caller in a loop can refill one buffer instead of allocating a
    /// fresh `Vec` per call.
    pub fn call_closure_in(
        &mut self,
        c: &RClosure,
        args: &mut Vec<(Option<String>, RVal)>,
        fenv: &EnvRef,
    ) -> EvalResult {
        // `...` comparisons are u32 symbol compares, no interner access.
        let dots = sym_dots();
        let has_dots = c.params.iter().any(|p| p.name == dots);

        // Fast path: all-positional call of a dots-free closure with no
        // more arguments than parameters (the shape of virtually every
        // map body call). Binds directly — no partition scratch vectors.
        let simple =
            !has_dots && args.len() <= c.params.len() && args.iter().all(|(n, _)| n.is_none());
        if simple {
            let n_args = args.len();
            for (p, (_, val)) in c.params.iter().zip(args.drain(..)) {
                env::define_sym(fenv, p.name, val);
            }
            for p in &c.params[n_args..] {
                if let Some(d) = &p.default {
                    let v = self.eval(d, fenv)?;
                    env::define_sym(fenv, p.name, v);
                }
                // No default: missing — error only on use.
            }
            return match self.eval(&c.body, fenv) {
                Ok(v) => Ok(v),
                Err(Signal::Return(v)) => Ok(v),
                Err(e) => Err(e),
            };
        }

        // General path. Partition: named args match params by name;
        // positionals fill the rest in order; excess goes to `...` if
        // present.
        let mut bound = vec![false; c.params.len()];
        let mut positional: Vec<RVal> = Vec::new();
        let mut dots_args: Vec<(Option<String>, RVal)> = Vec::new();

        for (name, val) in args.drain(..) {
            match name {
                Some(n) => {
                    // Probe the interner once per named argument, then
                    // match parameters by u32 id (a name that was never
                    // interned cannot name a parameter).
                    let n_sym = Symbol::probe(&n);
                    let hit = n_sym
                        .and_then(|s| c.params.iter().position(|p| p.name == s));
                    if let Some(idx) = hit {
                        env::define_sym(fenv, c.params[idx].name, val);
                        bound[idx] = true;
                    } else if has_dots {
                        dots_args.push((Some(n), val));
                    } else {
                        return Err(Signal::error(format!("unused argument ({n} = ...)")));
                    }
                }
                None => positional.push(val),
            }
        }
        let mut pos_iter = positional.into_iter();
        for (idx, p) in c.params.iter().enumerate() {
            if p.name == dots {
                // Everything remaining goes to `...`.
                for v in pos_iter.by_ref() {
                    dots_args.push((None, v));
                }
                continue;
            }
            if bound[idx] {
                continue;
            }
            if let Some(v) = pos_iter.next() {
                env::define_sym(fenv, p.name, v);
                bound[idx] = true;
            }
        }
        // Leftover positionals without a `...` param: error (R semantics).
        if !has_dots {
            let leftovers: Vec<RVal> = pos_iter.collect();
            if !leftovers.is_empty() {
                return Err(Signal::error("unused arguments in call"));
            }
        }
        if has_dots {
            let names: Vec<String> =
                dots_args.iter().map(|(n, _)| n.clone().unwrap_or_default()).collect();
            let vals: Vec<RVal> = dots_args.into_iter().map(|(_, v)| v).collect();
            let named = names.iter().any(|n| !n.is_empty());
            env::define_sym(
                fenv,
                dots,
                RVal::List(RList {
                    vals,
                    names: if named { Some(names) } else { None },
                    class: None,
                }),
            );
        }
        // Defaults for still-unbound params (evaluated in the new frame).
        for (idx, p) in c.params.iter().enumerate() {
            if p.name == dots || bound[idx] {
                continue;
            }
            match &p.default {
                Some(d) => {
                    let v = self.eval(d, fenv)?;
                    env::define_sym(fenv, p.name, v);
                }
                None => { /* missing — error only on use */ }
            }
        }
        match self.eval(&c.body, fenv) {
            Ok(v) => Ok(v),
            Err(Signal::Return(v)) => Ok(v),
            Err(e) => Err(e),
        }
    }

    fn assign(&mut self, target: &Expr, value: RVal, env: &EnvRef) -> Result<(), Signal> {
        match target {
            Expr::Sym(name) => {
                env::define_sym(env, *name, value);
                Ok(())
            }
            Expr::Str(name) => {
                env::define(env, name, value);
                Ok(())
            }
            Expr::Index { obj, args, double } => {
                let mut base = self.eval(obj, env)?;
                let idx: Vec<RVal> = args
                    .iter()
                    .map(|a| self.eval(&a.value, env))
                    .collect::<Result<_, _>>()?;
                index_set(&mut base, &idx, *double, value).map_err(Signal::error)?;
                self.assign(obj, base, env)
            }
            Expr::Dollar { obj, name } => {
                let base = self.eval(obj, env)?;
                match base {
                    RVal::List(mut l) => {
                        l.set(name, value);
                        self.assign(obj, RVal::List(l), env)
                    }
                    RVal::Env(e) => {
                        env::define(&e, name, value);
                        Ok(())
                    }
                    other => {
                        Err(Signal::error(format!("$<- invalid for {}", other.class())))
                    }
                }
            }
            Expr::Call { func, args } if matches!(func.as_ref(), Expr::Sym(s) if s == "names") => {
                // names(x) <- value
                let inner = &args[0].value;
                let mut base = self.eval(inner, env)?;
                let names = if value.is_null() {
                    None
                } else {
                    Some(value.as_str_vec().map_err(Signal::error)?)
                };
                base.set_names(names);
                self.assign(inner, base, env)
            }
            other => Err(Signal::error(format!("invalid assignment target: {}", deparse(other)))),
        }
    }
}

// ---- indexing helpers ------------------------------------------------------

fn resolve_indices(idx: &RVal, len: usize, names: Option<&[String]>) -> Result<Vec<usize>, String> {
    match idx {
        RVal::Lgl(mask) => {
            let mut out = Vec::new();
            for (i, &b) in mask.vals.iter().enumerate() {
                if b {
                    out.push(i);
                }
            }
            // Recycle mask if shorter than vector.
            if mask.len() < len && !mask.vals.is_empty() {
                out.clear();
                for i in 0..len {
                    if mask.vals[i % mask.vals.len()] {
                        out.push(i);
                    }
                }
            }
            Ok(out)
        }
        RVal::Chr(keys) => {
            let names = names.ok_or("cannot index unnamed vector by name")?;
            keys.vals
                .iter()
                .map(|k| {
                    names
                        .iter()
                        .position(|n| n == k)
                        .ok_or_else(|| format!("subscript '{k}' out of bounds"))
                })
                .collect()
        }
        other => {
            let nums = other.as_dbl_vec()?;
            // All-negative: exclusion.
            if !nums.is_empty() && nums.iter().all(|&x| x < 0.0) {
                let excl: std::collections::HashSet<usize> =
                    nums.iter().map(|&x| (-x) as usize - 1).collect();
                return Ok((0..len).filter(|i| !excl.contains(i)).collect());
            }
            nums.iter()
                .map(|&x| {
                    let i = x as i64;
                    if i < 1 || i as usize > len {
                        Err(format!("subscript out of bounds ({i} of {len})"))
                    } else {
                        Ok(i as usize - 1)
                    }
                })
                .collect()
        }
    }
}

/// `x[i]` and `x[[i]]`.
pub fn index_get(obj: &RVal, idx: &[RVal], double: bool) -> Result<RVal, String> {
    if idx.len() != 1 {
        // Multi-dim indexing: support df[i, j] for data.frame-ish lists.
        if let RVal::List(l) = obj {
            if idx.len() == 2 {
                // columns first
                let cols: Vec<usize> = match &idx[1] {
                    RVal::Null => (0..l.len()).collect(),
                    other => resolve_indices(other, l.len(), l.names.as_deref())?,
                };
                let nrow = l.vals.first().map(|c| c.len()).unwrap_or(0);
                let rows: Vec<usize> = match &idx[0] {
                    RVal::Null => (0..nrow).collect(),
                    other => resolve_indices(other, nrow, None)?,
                };
                let mut out_vals = Vec::new();
                let mut out_names = Vec::new();
                for &c in &cols {
                    let col = &l.vals[c];
                    let picked = index_get(
                        col,
                        &[RVal::dbl(rows.iter().map(|&r| (r + 1) as f64).collect())],
                        false,
                    )?;
                    out_vals.push(picked);
                    if let Some(ns) = &l.names {
                        out_names.push(ns[c].clone());
                    }
                }
                let mut out = RList::plain(out_vals);
                if !out_names.is_empty() {
                    out.names = Some(out_names);
                }
                out.class = l.class.clone();
                return Ok(RVal::List(out));
            }
        }
        return Err(format!("unsupported index arity {}", idx.len()));
    }
    let i = &idx[0];
    match obj {
        RVal::List(l) => {
            let ids = resolve_indices(i, l.len(), l.names.as_deref())?;
            if double {
                let id = *ids.first().ok_or("subscript out of bounds")?;
                Ok(l.vals[id].clone())
            } else {
                let vals: Vec<RVal> = ids.iter().map(|&i| l.vals[i].clone()).collect();
                let names = l.names.as_ref().map(|ns| ids.iter().map(|&i| ns[i].clone()).collect());
                Ok(RVal::List(RList { vals, names, class: None }))
            }
        }
        RVal::Dbl(v) => {
            let ids = resolve_indices(i, v.len(), v.names.as_deref())?;
            pick_vec(&v.vals, v.names.as_deref(), &ids, double, RVal::Dbl)
        }
        RVal::Int(v) => {
            let ids = resolve_indices(i, v.len(), v.names.as_deref())?;
            pick_vec(&v.vals, v.names.as_deref(), &ids, double, RVal::Int)
        }
        RVal::Chr(v) => {
            let ids = resolve_indices(i, v.len(), v.names.as_deref())?;
            pick_vec(&v.vals, v.names.as_deref(), &ids, double, RVal::Chr)
        }
        RVal::Lgl(v) => {
            let ids = resolve_indices(i, v.len(), v.names.as_deref())?;
            pick_vec(&v.vals, v.names.as_deref(), &ids, double, RVal::Lgl)
        }
        other => Err(format!("cannot index {}", other.class())),
    }
}

fn pick_vec<T: Clone>(
    vals: &[T],
    names: Option<&[String]>,
    ids: &[usize],
    double: bool,
    wrap: fn(super::value::RVec<T>) -> RVal,
) -> Result<RVal, String> {
    if double {
        let id = *ids.first().ok_or("subscript out of bounds")?;
        Ok(wrap(super::value::RVec::plain(vec![vals[id].clone()])))
    } else {
        let picked: Vec<T> = ids.iter().map(|&i| vals[i].clone()).collect();
        let nm = names.map(|ns| ids.iter().map(|&i| ns[i].clone()).collect());
        Ok(wrap(super::value::RVec::with_names(picked, nm)))
    }
}

/// `x[i] <- v` / `x[[i]] <- v`.
pub fn index_set(obj: &mut RVal, idx: &[RVal], _double: bool, value: RVal) -> Result<(), String> {
    if idx.len() != 1 {
        return Err("unsupported assignment index arity".into());
    }
    match obj {
        RVal::List(l) => {
            let ids = resolve_indices(&idx[0], l.len().max(1), l.names.as_deref())
                .or_else(|_| -> Result<Vec<usize>, String> {
                    // Appending beyond the end: x[[n+1]] <- v
                    let n = idx[0].as_usize().map_err(|e| e)?;
                    Ok(vec![n - 1])
                })?;
            for &id in &ids {
                while l.vals.len() <= id {
                    l.vals.push(RVal::Null);
                    if let Some(ns) = &mut l.names {
                        ns.push(String::new());
                    }
                }
                l.vals[id] = value.clone();
            }
            Ok(())
        }
        RVal::Dbl(v) => {
            let ids = resolve_indices(&idx[0], v.len(), v.names.as_deref()).or_else(
                |_| -> Result<Vec<usize>, String> { Ok(vec![idx[0].as_usize()? - 1]) },
            )?;
            let val = value.as_f64()?;
            // Copy-on-write: detach the payload once, iff shared.
            let vals = v.vals_mut();
            for &id in &ids {
                while vals.len() <= id {
                    vals.push(f64::NAN);
                }
                vals[id] = val;
            }
            Ok(())
        }
        RVal::Int(v) => {
            let ids = resolve_indices(&idx[0], v.len(), v.names.as_deref())?;
            let val = value.as_i64()?;
            let vals = v.vals_mut();
            for &id in &ids {
                vals[id] = val;
            }
            Ok(())
        }
        RVal::Null => {
            // NULL grows into a list on assignment, as in R.
            let mut l = RList::plain(vec![]);
            let id = idx[0].as_usize()? - 1;
            while l.vals.len() <= id {
                l.vals.push(RVal::Null);
            }
            l.vals[id] = value;
            *obj = RVal::List(l);
            Ok(())
        }
        other => Err(format!("cannot assign into {}", other.class())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(src: &str) -> RVal {
        let mut i = Interp::new();
        i.eval_program(src).unwrap_or_else(|e| panic!("eval error in {src:?}: {e:?}"))
    }

    #[test]
    fn arithmetic_and_assignment() {
        assert_eq!(run("x <- 2\nx + 3"), RVal::scalar_dbl(5.0));
        assert_eq!(run("x <- 1:3\nsum(x)"), RVal::scalar_dbl(6.0));
    }

    #[test]
    fn closures_capture_lexically() {
        assert_eq!(run("a <- 10\nf <- function(x) x + a\nf(1)"), RVal::scalar_dbl(11.0));
    }

    #[test]
    fn default_arguments() {
        assert_eq!(run("f <- function(x, n = 2) x^n\nf(3)"), RVal::scalar_dbl(9.0));
        assert_eq!(run("f <- function(x, n = 2) x^n\nf(2, n = 3)"), RVal::scalar_dbl(8.0));
    }

    #[test]
    fn dots_forwarding() {
        assert_eq!(
            run("f <- function(...) sum(...)\nf(1, 2, 3)"),
            RVal::scalar_dbl(6.0)
        );
    }

    #[test]
    fn for_loop_accumulates() {
        assert_eq!(run("s <- 0\nfor (i in 1:10) s <- s + i\ns"), RVal::scalar_dbl(55.0));
    }

    #[test]
    fn while_with_break() {
        assert_eq!(
            run("i <- 0\nwhile (TRUE) { i <- i + 1\nif (i >= 5) break }\ni"),
            RVal::scalar_dbl(5.0)
        );
    }

    #[test]
    fn indexing_reads() {
        assert_eq!(run("x <- c(10, 20, 30)\nx[2]"), RVal::scalar_dbl(20.0));
        assert_eq!(run("x <- list(1, \"a\")\nx[[2]]"), RVal::scalar_str("a"));
        assert_eq!(run("x <- c(a = 1, b = 2)\nx[\"b\"]").as_f64().unwrap(), 2.0);
    }

    #[test]
    fn negative_indexing_excludes() {
        assert_eq!(run("x <- c(1, 2, 3)\nsum(x[-1])"), RVal::scalar_dbl(5.0));
    }

    #[test]
    fn index_assignment() {
        assert_eq!(run("x <- c(1, 2, 3)\nx[2] <- 9\nsum(x)"), RVal::scalar_dbl(13.0));
    }

    #[test]
    fn lambda_and_pipe() {
        assert_eq!(run("f <- \\(x) x * 2\nf(4)"), RVal::scalar_dbl(8.0));
        assert_eq!(run("4 |> sqrt()"), RVal::scalar_dbl(2.0));
    }

    #[test]
    fn super_assignment_mutates_enclosing() {
        assert_eq!(
            run("counter <- 0\nbump <- function() counter <<- counter + 1\nbump()\nbump()\ncounter"),
            RVal::scalar_dbl(2.0)
        );
    }

    #[test]
    fn error_signal_has_message() {
        let mut i = Interp::new();
        let err = i.eval_program("stop(\"boom\")").unwrap_err();
        match err {
            Signal::Error(c) => assert_eq!(c.message, "boom"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn object_not_found() {
        let mut i = Interp::new();
        let err = i.eval_program("nosuch + 1").unwrap_err();
        match err {
            Signal::Error(c) => assert!(c.message.contains("not found")),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn captured_eval_collects_output_and_conditions() {
        let mut i = Interp::new();
        let exprs = super::super::parse_program("{ cat(\"hi\")\nmessage(\"m1\")\n42 }").unwrap();
        let genv = i.global.clone();
        let (r, log) = i.eval_captured(&exprs[0], &genv);
        assert_eq!(r.unwrap(), RVal::scalar_dbl(42.0));
        assert_eq!(log.stdout, "hi");
        assert_eq!(log.conditions.len(), 1);
        assert!(log.conditions[0].inherits("message"));
    }

    #[test]
    fn relay_resignals_through_suppress() {
        let mut i = Interp::new();
        // Capture a message...
        let exprs = super::super::parse_program("message(\"x = 1\")").unwrap();
        let genv = i.global.clone();
        let (_, log) = i.eval_captured(&exprs[0], &genv);
        // ...relay under an active suppressor: nothing escapes.
        i.handlers.push(HandlerFrame::Suppress { classes: vec!["message".into()] });
        let ((), err_out) = {
            let (r, captured) = i.capture_stdout(|i| i.relay(&log).unwrap());
            (r, captured)
        };
        i.handlers.pop();
        assert_eq!(err_out, "");
    }

    #[test]
    fn data_frame_two_dim_index() {
        let v = run("df <- data.frame(a = 1:4, b = c(\"w\",\"x\",\"y\",\"z\"))\ndf[2, 1]");
        match v {
            RVal::List(l) => assert_eq!(l.vals[0].as_f64().unwrap(), 2.0),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn names_assignment() {
        let v = run("x <- c(1, 2)\nnames(x) <- c(\"a\", \"b\")\nx[\"a\"]");
        assert_eq!(v.as_f64().unwrap(), 1.0);
    }
}
