//! Lexically scoped environments.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use super::value::RVal;

/// A single environment frame: bindings plus an optional parent.
#[derive(Debug, Default)]
pub struct Env {
    pub vars: HashMap<String, RVal>,
    pub parent: Option<EnvRef>,
}

pub type EnvRef = Rc<RefCell<Env>>;

impl Env {
    pub fn new_ref() -> EnvRef {
        Rc::new(RefCell::new(Env::default()))
    }

    pub fn child_of(parent: &EnvRef) -> EnvRef {
        Rc::new(RefCell::new(Env { vars: HashMap::new(), parent: Some(parent.clone()) }))
    }
}

/// Look a symbol up through the environment chain.
pub fn lookup(env: &EnvRef, name: &str) -> Option<RVal> {
    let mut cur = env.clone();
    loop {
        if let Some(v) = cur.borrow().vars.get(name) {
            return Some(v.clone());
        }
        let parent = cur.borrow().parent.clone();
        match parent {
            Some(p) => cur = p,
            None => return None,
        }
    }
}

/// Bind `name` in the *current* frame (R's `<-` at local scope).
pub fn define(env: &EnvRef, name: &str, val: RVal) {
    env.borrow_mut().vars.insert(name.to_string(), val);
}

/// `exists()` through the chain.
pub fn exists(env: &EnvRef, name: &str) -> bool {
    lookup(env, name).is_some()
}

/// All bindings visible from `env` (outermost shadowed by innermost);
/// used by `eapply()` and globals export.
pub fn flatten(env: &EnvRef) -> Vec<(String, RVal)> {
    let mut seen = std::collections::HashSet::new();
    let mut out = Vec::new();
    let mut cur = Some(env.clone());
    while let Some(e) = cur {
        for (k, v) in e.borrow().vars.iter() {
            if seen.insert(k.clone()) {
                out.push((k.clone(), v.clone()));
            }
        }
        cur = e.borrow().parent.clone();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_walks_chain() {
        let root = Env::new_ref();
        define(&root, "x", RVal::scalar_dbl(1.0));
        let child = Env::child_of(&root);
        assert_eq!(lookup(&child, "x"), Some(RVal::scalar_dbl(1.0)));
        define(&child, "x", RVal::scalar_dbl(2.0));
        assert_eq!(lookup(&child, "x"), Some(RVal::scalar_dbl(2.0)));
        assert_eq!(lookup(&root, "x"), Some(RVal::scalar_dbl(1.0)));
    }

    #[test]
    fn flatten_shadows() {
        let root = Env::new_ref();
        define(&root, "x", RVal::scalar_dbl(1.0));
        define(&root, "y", RVal::scalar_dbl(3.0));
        let child = Env::child_of(&root);
        define(&child, "x", RVal::scalar_dbl(2.0));
        let flat = flatten(&child);
        let x = flat.iter().find(|(k, _)| k == "x").unwrap();
        assert_eq!(x.1, RVal::scalar_dbl(2.0));
        assert_eq!(flat.len(), 2);
    }
}
