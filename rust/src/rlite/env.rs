//! Lexically scoped environments.
//!
//! Frames are keyed by interned [`Symbol`]s. The common call frame has a
//! handful of bindings, so storage is a linear-scan `Vec<(Symbol, RVal)>`
//! (u32 compares, cache-friendly, zero hashing); frames that grow past
//! [`SMALL_FRAME_MAX`] bindings (the global env, generated test
//! environments) build a `Symbol → slot` hash index on the side. The
//! `&str`-keyed entry points intern on the way in, so cold callers
//! (builtins, tests, embedders) keep the old API while the evaluator's
//! hot paths use the `_sym` variants.

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::rc::Rc;

use super::intern::Symbol;
use super::value::RVal;

/// Bindings above which a frame builds a hash index.
pub const SMALL_FRAME_MAX: usize = 8;

/// Binding storage of one environment frame.
#[derive(Debug, Default)]
pub struct Frame {
    /// Insertion-ordered bindings; the single source of truth.
    entries: Vec<(Symbol, RVal)>,
    /// `Symbol → entries index`, built once the frame outgrows the
    /// linear-scan regime.
    index: Option<Box<HashMap<Symbol, usize>>>,
}

impl Frame {
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    fn slot(&self, sym: Symbol) -> Option<usize> {
        match &self.index {
            Some(ix) => ix.get(&sym).copied(),
            None => self.entries.iter().position(|(s, _)| *s == sym),
        }
    }

    pub fn get(&self, sym: Symbol) -> Option<&RVal> {
        self.slot(sym).map(|i| &self.entries[i].1)
    }

    pub fn contains(&self, sym: Symbol) -> bool {
        self.slot(sym).is_some()
    }

    pub fn insert(&mut self, sym: Symbol, val: RVal) {
        match self.slot(sym) {
            Some(i) => self.entries[i].1 = val,
            None => {
                let i = self.entries.len();
                self.entries.push((sym, val));
                if let Some(ix) = &mut self.index {
                    ix.insert(sym, i);
                } else if self.entries.len() > SMALL_FRAME_MAX {
                    let mut ix = Box::new(HashMap::with_capacity(self.entries.len() * 2));
                    for (k, (s, _)) in self.entries.iter().enumerate() {
                        ix.insert(*s, k);
                    }
                    self.index = Some(ix);
                }
            }
        }
    }

    /// Drop all bindings but keep the entry buffer's capacity — the
    /// frame-reuse fast path in the per-element map loop.
    pub fn clear(&mut self) {
        self.entries.clear();
        self.index = None;
    }

    pub fn iter(&self) -> impl Iterator<Item = (Symbol, &RVal)> {
        self.entries.iter().map(|(s, v)| (*s, v))
    }
}

/// A single environment frame: bindings plus an optional parent.
#[derive(Debug, Default)]
pub struct Env {
    pub vars: Frame,
    pub parent: Option<EnvRef>,
}

pub type EnvRef = Rc<RefCell<Env>>;

thread_local! {
    /// Count of environment frames heap-allocated on this thread — the
    /// observable behind the "zero per-element frame allocations" claim
    /// (asserted in tests and reported by `benches/interp_micro.rs`).
    static FRAMES_ALLOCATED: Cell<u64> = const { Cell::new(0) };
}

/// Frames allocated on this thread so far (monotone counter).
pub fn frames_allocated() -> u64 {
    FRAMES_ALLOCATED.with(|c| c.get())
}

fn count_frame_alloc() {
    FRAMES_ALLOCATED.with(|c| c.set(c.get() + 1));
}

impl Env {
    pub fn new_ref() -> EnvRef {
        count_frame_alloc();
        Rc::new(RefCell::new(Env::default()))
    }

    pub fn child_of(parent: &EnvRef) -> EnvRef {
        count_frame_alloc();
        Rc::new(RefCell::new(Env { vars: Frame::default(), parent: Some(parent.clone()) }))
    }
}

/// Look a symbol up through the environment chain.
pub fn lookup_sym(env: &EnvRef, sym: Symbol) -> Option<RVal> {
    let mut cur = env.clone();
    loop {
        if let Some(v) = cur.borrow().vars.get(sym) {
            return Some(v.clone());
        }
        let parent = cur.borrow().parent.clone();
        match parent {
            Some(p) => cur = p,
            None => return None,
        }
    }
}

/// `&str` entry point. A read probes the interner without inserting: a
/// never-interned name cannot be bound anywhere, and probing keeps
/// dynamic-name reads (`get(paste0(..))`) from leaking interner slots.
pub fn lookup(env: &EnvRef, name: &str) -> Option<RVal> {
    lookup_sym(env, Symbol::probe(name)?)
}

/// Bind `sym` in the *current* frame (R's `<-` at local scope).
pub fn define_sym(env: &EnvRef, sym: Symbol, val: RVal) {
    env.borrow_mut().vars.insert(sym, val);
}

/// `&str` entry point for [`define_sym`].
pub fn define(env: &EnvRef, name: &str, val: RVal) {
    define_sym(env, Symbol::intern(name), val);
}

/// `exists()` through the chain — a non-cloning walk (the found value is
/// never materialized, unlike `lookup(..).is_some()`).
pub fn exists_sym(env: &EnvRef, sym: Symbol) -> bool {
    let mut cur = env.clone();
    loop {
        if cur.borrow().vars.contains(sym) {
            return true;
        }
        let parent = cur.borrow().parent.clone();
        match parent {
            Some(p) => cur = p,
            None => return false,
        }
    }
}

/// `&str` entry point for [`exists_sym`] (read-only interner probe).
pub fn exists(env: &EnvRef, name: &str) -> bool {
    match Symbol::probe(name) {
        Some(sym) => exists_sym(env, sym),
        None => false,
    }
}

/// All bindings visible from `env` (outermost shadowed by innermost);
/// used by `eapply()` and globals export. Values are snapshotted
/// (cheaply, under copy-on-write) at call time.
pub fn flatten(env: &EnvRef) -> Vec<(String, RVal)> {
    let mut seen = std::collections::HashSet::new();
    let mut out = Vec::new();
    let mut cur = Some(env.clone());
    while let Some(e) = cur {
        for (sym, v) in e.borrow().vars.iter() {
            if seen.insert(sym) {
                out.push((sym.to_string(), v.clone()));
            }
        }
        cur = e.borrow().parent.clone();
    }
    out
}

/// The bindings of `env`'s own frame only (no parents), as owned pairs —
/// the `eapply()` surface.
pub fn local_bindings(env: &EnvRef) -> Vec<(String, RVal)> {
    env.borrow().vars.iter().map(|(s, v)| (s.to_string(), v.clone())).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_walks_chain() {
        let root = Env::new_ref();
        define(&root, "x", RVal::scalar_dbl(1.0));
        let child = Env::child_of(&root);
        assert_eq!(lookup(&child, "x"), Some(RVal::scalar_dbl(1.0)));
        define(&child, "x", RVal::scalar_dbl(2.0));
        assert_eq!(lookup(&child, "x"), Some(RVal::scalar_dbl(2.0)));
        assert_eq!(lookup(&root, "x"), Some(RVal::scalar_dbl(1.0)));
    }

    #[test]
    fn flatten_shadows() {
        let root = Env::new_ref();
        define(&root, "x", RVal::scalar_dbl(1.0));
        define(&root, "y", RVal::scalar_dbl(3.0));
        let child = Env::child_of(&root);
        define(&child, "x", RVal::scalar_dbl(2.0));
        let flat = flatten(&child);
        let x = flat.iter().find(|(k, _)| k == "x").unwrap();
        assert_eq!(x.1, RVal::scalar_dbl(2.0));
        assert_eq!(flat.len(), 2);
    }

    #[test]
    fn frame_spills_to_index_past_small_max() {
        let env = Env::new_ref();
        for k in 0..(SMALL_FRAME_MAX * 3) {
            define(&env, &format!("v{k}"), RVal::scalar_int(k as i64));
        }
        for k in 0..(SMALL_FRAME_MAX * 3) {
            assert_eq!(
                lookup(&env, &format!("v{k}")),
                Some(RVal::scalar_int(k as i64)),
                "binding v{k} must survive the spill"
            );
        }
        // Overwrite through the index path.
        define(&env, "v3", RVal::scalar_int(-3));
        assert_eq!(lookup(&env, "v3"), Some(RVal::scalar_int(-3)));
        assert_eq!(env.borrow().vars.len(), SMALL_FRAME_MAX * 3);
    }

    #[test]
    fn exists_without_cloning() {
        let env = Env::new_ref();
        define(&env, "big", RVal::dbl(vec![0.0; 4096]));
        assert!(exists(&env, "big"));
        assert!(!exists(&env, "missing"));
    }

    #[test]
    fn read_paths_do_not_intern_missing_names() {
        // Probing a never-bound name must not grow the interner: the
        // probe comes back absent both before and after the lookup.
        let env = Env::new_ref();
        let name = "never_bound_probe_only_name_xyz";
        assert!(Symbol::probe(name).is_none());
        assert!(lookup(&env, name).is_none());
        assert!(!exists(&env, name));
        assert!(Symbol::probe(name).is_none(), "read must not intern");
        // Defining interns as usual.
        define(&env, name, RVal::scalar_dbl(1.0));
        assert!(Symbol::probe(name).is_some());
        assert!(exists(&env, name));
    }

    #[test]
    fn clear_keeps_frame_usable() {
        let env = Env::new_ref();
        define(&env, "a", RVal::scalar_dbl(1.0));
        env.borrow_mut().vars.clear();
        assert!(lookup(&env, "a").is_none());
        define(&env, "b", RVal::scalar_dbl(2.0));
        assert_eq!(lookup(&env, "b"), Some(RVal::scalar_dbl(2.0)));
    }

    #[test]
    fn allocation_counter_ticks() {
        let before = frames_allocated();
        let e = Env::new_ref();
        let _c = Env::child_of(&e);
        assert_eq!(frames_allocated() - before, 2);
    }
}
