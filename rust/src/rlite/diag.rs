//! Diagnostics model for the parallel-safety analyzer
//! (`transpile::analysis`).
//!
//! Every diagnostic carries a stable code (`FZ001`, ...), a severity, the
//! deparsed offending sub-expression, a human message and a concrete fix
//! hint. rlite's [`Expr`](super::ast::Expr) carries no source positions
//! (adding them would change the wire format every backend speaks), so
//! the deparsed snippet *is* the span: precise enough to locate the
//! construct, stable across codecs.

use std::fmt;

/// How lint findings are surfaced, `futurize(lint = ...)`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum LintMode {
    /// Skip the analysis entirely.
    Off,
    /// Relay Warn-level findings through the ordered condition relay,
    /// once per map call, then execute normally (the default).
    #[default]
    Warn,
    /// Promote Warn-level findings to a classed `FuturizeLintError`
    /// condition raised at freeze time, before any worker is touched.
    Error,
}

impl LintMode {
    pub fn parse(s: &str) -> Option<LintMode> {
        match s {
            "off" => Some(LintMode::Off),
            "warn" => Some(LintMode::Warn),
            "error" => Some(LintMode::Error),
            _ => None,
        }
    }
}

/// Environment override for the lint mode — the operator's kill switch
/// (`FUTURIZE_LINT=off`) and promotion lever (`FUTURIZE_LINT=error`).
pub const LINT_ENV: &str = "FUTURIZE_LINT";

/// The effective mode for one map call: the env var, when set to a
/// valid mode, overrides the per-call option. Read per call (not
/// cached) so tests and operators can toggle it without restarting.
pub fn effective_mode(opt: LintMode) -> LintMode {
    match std::env::var(LINT_ENV) {
        Ok(v) => LintMode::parse(&v).unwrap_or(opt),
        Err(_) => opt,
    }
}

/// Per-map-call lint configuration distilled into
/// [`MapOptions`](crate::future_core::driver::MapOptions). Besides the
/// mode it carries the reduction facts the freeze-time analyzer needs
/// but that `MapOptions::reduce` no longer encodes once a combine fails
/// to map onto a worker-side plan.
#[derive(Clone, Debug, Default)]
pub struct LintSettings {
    pub mode: LintMode,
    /// The user asked for `reduce = "assoc"` (reassociated FP folding).
    pub assoc_requested: bool,
    /// The recognized reduction head/combine symbol, if any.
    pub reduce_op: Option<String>,
    /// A combine function that cannot be proven associative (a user
    /// `.combine`), by display name.
    pub nonassoc_combine: Option<String>,
    /// Why no worker-side fold plan was attached despite a reduction
    /// being requested (shadowed outer symbol, op not in the catalog).
    pub reduce_rejected: Option<String>,
}

/// Severity of one finding, ordered `Info < Warn < Error`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum LintLevel {
    /// Explanatory only (fusion-rejection reasons, ULP contract notes):
    /// shown by the `lint` CLI and `fusion_report()`, never relayed.
    Info,
    /// Relayed as a warning; promoted to an error under
    /// `lint = "error"`.
    Warn,
    /// Always raises before dispatch.
    Error,
}

impl LintLevel {
    pub fn label(self) -> &'static str {
        match self {
            LintLevel::Info => "info",
            LintLevel::Warn => "warn",
            LintLevel::Error => "error",
        }
    }
}

/// Stable diagnostic codes. Codes are append-only: a released code never
/// changes meaning, so scripts and CI greps can pin them.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DiagCode {
    /// FZ001 — cross-iteration dependence: `<<-`/`assign()` into a
    /// binding the body also reads, so element i depends on i-1.
    CrossIterationDependence,
    /// FZ002 — RNG builtins in a body without `seed = TRUE`.
    NonReproducibleRng,
    /// FZ003 — a free variable that resolves to nothing at freeze time
    /// (would surface as a worker-side "not found" error).
    UnresolvableGlobal,
    /// FZ004 — the captured/global export exceeds the size threshold.
    OversizedCapture,
    /// FZ005 — a combine that cannot be proven associative under
    /// `reduce = "assoc"`.
    OrderDependentReduction,
    /// FZ006 — a floating-point fold opted into `reduce = "assoc"`
    /// (the documented last-ULPs contract applies).
    FloatFoldUlp,
    /// FZ007 — kernel fusion rejected this body; names the blocker.
    KernelFusionRejected,
    /// FZ008 — reduction fusion rejected this call; names the blocker.
    ReduceFusionRejected,
    /// FZ009 — data-plane cache activity for this map call: how many
    /// blobs were extracted and the session's running hit/miss
    /// counters (`fusion_report()` carries the same numbers).
    CacheReport,
}

impl DiagCode {
    pub fn as_str(self) -> &'static str {
        match self {
            DiagCode::CrossIterationDependence => "FZ001",
            DiagCode::NonReproducibleRng => "FZ002",
            DiagCode::UnresolvableGlobal => "FZ003",
            DiagCode::OversizedCapture => "FZ004",
            DiagCode::OrderDependentReduction => "FZ005",
            DiagCode::FloatFoldUlp => "FZ006",
            DiagCode::KernelFusionRejected => "FZ007",
            DiagCode::ReduceFusionRejected => "FZ008",
            DiagCode::CacheReport => "FZ009",
        }
    }

    /// The level a finding of this code carries before any promotion.
    pub fn default_level(self) -> LintLevel {
        match self {
            DiagCode::CrossIterationDependence
            | DiagCode::NonReproducibleRng
            | DiagCode::UnresolvableGlobal
            | DiagCode::OversizedCapture
            | DiagCode::OrderDependentReduction => LintLevel::Warn,
            DiagCode::FloatFoldUlp
            | DiagCode::KernelFusionRejected
            | DiagCode::ReduceFusionRejected
            | DiagCode::CacheReport => LintLevel::Info,
        }
    }
}

/// One analyzer finding.
#[derive(Clone, Debug, PartialEq)]
pub struct Diagnostic {
    pub code: DiagCode,
    pub level: LintLevel,
    /// Deparsed offending sub-expression (the "span").
    pub snippet: String,
    pub message: String,
    /// A concrete, actionable fix.
    pub hint: String,
}

impl Diagnostic {
    pub fn new(
        code: DiagCode,
        snippet: impl Into<String>,
        message: impl Into<String>,
        hint: impl Into<String>,
    ) -> Diagnostic {
        Diagnostic {
            code,
            level: code.default_level(),
            snippet: snippet.into(),
            message: message.into(),
            hint: hint.into(),
        }
    }

    /// One-line rendering used in relayed warnings and raised errors.
    pub fn render(&self) -> String {
        format!(
            "{} [{}] `{}`: {} (fix: {})",
            self.code.as_str(),
            self.level.label(),
            self.snippet,
            self.message,
            self.hint
        )
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.render())
    }
}

/// Render findings as the aligned table the `futurize-rs lint`
/// subcommand prints.
pub fn render_table(diags: &[Diagnostic]) -> String {
    let mut out = String::new();
    let wide = |s: &str, w: usize| format!("{s:<w$}");
    out.push_str(&format!(
        "{}  {}  {:<40}  {}\n",
        wide("CODE", 6),
        wide("LEVEL", 5),
        "EXPRESSION",
        "MESSAGE"
    ));
    for d in diags {
        let snippet = if d.snippet.chars().count() > 40 {
            let head: String = d.snippet.chars().take(37).collect();
            format!("{head}...")
        } else {
            d.snippet.clone()
        };
        out.push_str(&format!(
            "{}  {}  {:<40}  {} (fix: {})\n",
            wide(d.code.as_str(), 6),
            wide(d.level.label(), 5),
            snippet,
            d.message,
            d.hint
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_are_stable_and_levelled() {
        assert_eq!(DiagCode::CrossIterationDependence.as_str(), "FZ001");
        assert_eq!(DiagCode::ReduceFusionRejected.as_str(), "FZ008");
        assert_eq!(DiagCode::CacheReport.as_str(), "FZ009");
        assert_eq!(DiagCode::CrossIterationDependence.default_level(), LintLevel::Warn);
        assert_eq!(DiagCode::KernelFusionRejected.default_level(), LintLevel::Info);
        assert_eq!(DiagCode::CacheReport.default_level(), LintLevel::Info);
        assert!(LintLevel::Info < LintLevel::Warn && LintLevel::Warn < LintLevel::Error);
    }

    #[test]
    fn mode_parses_and_env_overrides() {
        assert_eq!(LintMode::parse("warn"), Some(LintMode::Warn));
        assert_eq!(LintMode::parse("error"), Some(LintMode::Error));
        assert_eq!(LintMode::parse("off"), Some(LintMode::Off));
        assert_eq!(LintMode::parse("loud"), None);
        // Without the env var the option wins (the var is absent in the
        // test environment unless a CI leg sets it globally).
        if std::env::var(LINT_ENV).is_err() {
            assert_eq!(effective_mode(LintMode::Error), LintMode::Error);
        }
    }

    #[test]
    fn render_carries_code_and_hint() {
        let d = Diagnostic::new(
            DiagCode::CrossIterationDependence,
            "total <<- total + x",
            "body mutates a binding it also reads",
            "use a reduction instead",
        );
        let s = d.render();
        assert!(s.contains("FZ001") && s.contains("fix:"), "{s}");
        let t = render_table(std::slice::from_ref(&d));
        assert!(t.contains("FZ001") && t.contains("total <<- total + x"), "{t}");
    }
}
