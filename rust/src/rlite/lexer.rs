//! Lexer for rlite source text.
//!
//! Token-level compatibility with the R subset used throughout the paper:
//! numeric literals (`1`, `2.5`, `1e3`, `42L`), strings with escapes,
//! identifiers (including dotted names like `cv.glmnet` and
//! backtick-quoted names), the native pipe `|>`, user infix operators
//! `%do%`/`%dofuture%`/`%%`/`%/%`/`%in%`, lambdas `\(x)`, and both
//! assignment arrows.

/// A lexical token with its source position (for error messages).
#[derive(Clone, Debug, PartialEq)]
pub struct Token {
    pub kind: Tok,
    pub line: usize,
    pub col: usize,
}

#[derive(Clone, Debug, PartialEq)]
pub enum Tok {
    Num(f64),
    Int(i64),
    Str(String),
    Ident(String),
    /// `%op%` user infix (the full text including percent signs)
    Infix(String),
    /// Keywords
    Function,
    Backslash, // \(x) lambda introducer
    If,
    Else,
    For,
    While,
    In,
    Break,
    Next,
    True,
    False,
    Null,
    Na,
    Inf,
    NaN,
    /// Punctuation / operators
    LParen,
    RParen,
    LBrace,
    RBrace,
    LBracket,        // [
    RBracket,        // ]
    DoubleLBracket,  // [[
    DoubleRBracket,  // ]]
    Comma,
    Semi,
    Newline,
    Plus,
    Minus,
    Star,
    Slash,
    Caret,
    Question,
    Tilde,
    Bang,
    Eq,       // =
    EqEq,     // ==
    Neq,      // !=
    Lt,
    Gt,
    Le,
    Ge,
    And,      // &
    AndAnd,   // &&
    Or,       // |
    OrOr,     // ||
    Pipe,     // |>
    LeftAssign,   // <-
    SuperAssign,  // <<-
    RightAssign,  // ->
    DoubleColon,  // ::
    Colon,        // :
    Dollar,
    Dots,     // ...
}

struct Lexer<'a> {
    src: &'a [u8],
    pos: usize,
    line: usize,
    col: usize,
}

impl<'a> Lexer<'a> {
    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }
    fn peek2(&self) -> Option<u8> {
        self.src.get(self.pos + 1).copied()
    }
    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        if c == b'\n' {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        Some(c)
    }
    fn err(&self, msg: &str) -> String {
        format!("lex error at {}:{}: {}", self.line, self.col, msg)
    }
}

fn is_ident_start(c: u8) -> bool {
    c.is_ascii_alphabetic() || c == b'.' || c == b'_'
}
fn is_ident_cont(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'.' || c == b'_'
}

/// Tokenize `src` into a flat token stream. Newlines are kept as tokens
/// (they terminate statements, as in R) and comments are stripped.
pub fn lex(src: &str) -> Result<Vec<Token>, String> {
    let mut lx = Lexer { src: src.as_bytes(), pos: 0, line: 1, col: 1 };
    let mut out = Vec::new();
    loop {
        let (line, col) = (lx.line, lx.col);
        let c = match lx.peek() {
            None => break,
            Some(c) => c,
        };
        let kind = match c {
            b' ' | b'\t' | b'\r' => {
                lx.bump();
                continue;
            }
            b'#' => {
                while let Some(c) = lx.peek() {
                    if c == b'\n' {
                        break;
                    }
                    lx.bump();
                }
                continue;
            }
            b'\n' => {
                lx.bump();
                Tok::Newline
            }
            b'(' => { lx.bump(); Tok::LParen }
            b')' => { lx.bump(); Tok::RParen }
            b'{' => { lx.bump(); Tok::LBrace }
            b'}' => { lx.bump(); Tok::RBrace }
            b'[' => {
                lx.bump();
                if lx.peek() == Some(b'[') {
                    lx.bump();
                    Tok::DoubleLBracket
                } else {
                    Tok::LBracket
                }
            }
            b']' => {
                lx.bump();
                if lx.peek() == Some(b']') {
                    lx.bump();
                    Tok::DoubleRBracket
                } else {
                    Tok::RBracket
                }
            }
            b',' => { lx.bump(); Tok::Comma }
            b';' => { lx.bump(); Tok::Semi }
            b'+' => { lx.bump(); Tok::Plus }
            b'*' => { lx.bump(); Tok::Star }
            b'/' => { lx.bump(); Tok::Slash }
            b'^' => { lx.bump(); Tok::Caret }
            b'?' => { lx.bump(); Tok::Question }
            b'~' => { lx.bump(); Tok::Tilde }
            b'$' => { lx.bump(); Tok::Dollar }
            b'\\' => { lx.bump(); Tok::Backslash }
            b'-' => {
                lx.bump();
                if lx.peek() == Some(b'>') {
                    lx.bump();
                    Tok::RightAssign
                } else {
                    Tok::Minus
                }
            }
            b'!' => {
                lx.bump();
                if lx.peek() == Some(b'=') {
                    lx.bump();
                    Tok::Neq
                } else {
                    Tok::Bang
                }
            }
            b'=' => {
                lx.bump();
                if lx.peek() == Some(b'=') {
                    lx.bump();
                    Tok::EqEq
                } else {
                    Tok::Eq
                }
            }
            b'<' => {
                lx.bump();
                match lx.peek() {
                    Some(b'-') => { lx.bump(); Tok::LeftAssign }
                    Some(b'=') => { lx.bump(); Tok::Le }
                    Some(b'<') if lx.peek2() == Some(b'-') => {
                        lx.bump();
                        lx.bump();
                        Tok::SuperAssign
                    }
                    _ => Tok::Lt,
                }
            }
            b'>' => {
                lx.bump();
                if lx.peek() == Some(b'=') {
                    lx.bump();
                    Tok::Ge
                } else {
                    Tok::Gt
                }
            }
            b'&' => {
                lx.bump();
                if lx.peek() == Some(b'&') {
                    lx.bump();
                    Tok::AndAnd
                } else {
                    Tok::And
                }
            }
            b'|' => {
                lx.bump();
                match lx.peek() {
                    Some(b'|') => { lx.bump(); Tok::OrOr }
                    Some(b'>') => { lx.bump(); Tok::Pipe }
                    _ => Tok::Or,
                }
            }
            b':' => {
                lx.bump();
                if lx.peek() == Some(b':') {
                    lx.bump();
                    Tok::DoubleColon
                } else {
                    Tok::Colon
                }
            }
            b'%' => {
                // user infix: %...%
                lx.bump();
                let mut name = String::from("%");
                loop {
                    match lx.bump() {
                        Some(b'%') => {
                            name.push('%');
                            break;
                        }
                        Some(c) => name.push(c as char),
                        None => return Err(lx.err("unterminated %infix%")),
                    }
                }
                Tok::Infix(name)
            }
            b'"' | b'\'' => {
                let quote = c;
                lx.bump();
                let mut s = String::new();
                loop {
                    match lx.bump() {
                        Some(c) if c == quote => break,
                        Some(b'\\') => match lx.bump() {
                            Some(b'n') => s.push('\n'),
                            Some(b't') => s.push('\t'),
                            Some(b'\\') => s.push('\\'),
                            Some(b'"') => s.push('"'),
                            Some(b'\'') => s.push('\''),
                            Some(c) => s.push(c as char),
                            None => return Err(lx.err("unterminated string")),
                        },
                        Some(c) => s.push(c as char),
                        None => return Err(lx.err("unterminated string")),
                    }
                }
                Tok::Str(s)
            }
            b'`' => {
                lx.bump();
                let mut s = String::new();
                loop {
                    match lx.bump() {
                        Some(b'`') => break,
                        Some(c) => s.push(c as char),
                        None => return Err(lx.err("unterminated backtick name")),
                    }
                }
                Tok::Ident(s)
            }
            c if c.is_ascii_digit()
                || (c == b'.' && lx.peek2().map_or(false, |d| d.is_ascii_digit())) =>
            {
                let start = lx.pos;
                while let Some(c) = lx.peek() {
                    if c.is_ascii_digit() || c == b'.' {
                        lx.bump();
                    } else if c == b'e' || c == b'E' {
                        // exponent
                        let save = lx.pos;
                        lx.bump();
                        if matches!(lx.peek(), Some(b'+') | Some(b'-')) {
                            lx.bump();
                        }
                        if lx.peek().map_or(false, |d| d.is_ascii_digit()) {
                            while lx.peek().map_or(false, |d| d.is_ascii_digit()) {
                                lx.bump();
                            }
                        } else {
                            lx.pos = save;
                            break;
                        }
                    } else {
                        break;
                    }
                }
                let text = std::str::from_utf8(&lx.src[start..lx.pos]).unwrap();
                if lx.peek() == Some(b'L') {
                    lx.bump();
                    let v: i64 = text
                        .parse::<f64>()
                        .map_err(|e| lx.err(&format!("bad integer {text}: {e}")))?
                        as i64;
                    Tok::Int(v)
                } else {
                    let v: f64 =
                        text.parse().map_err(|e| lx.err(&format!("bad number {text}: {e}")))?;
                    Tok::Num(v)
                }
            }
            c if is_ident_start(c) => {
                let start = lx.pos;
                while lx.peek().map_or(false, is_ident_cont) {
                    lx.bump();
                }
                let text = std::str::from_utf8(&lx.src[start..lx.pos]).unwrap().to_string();
                match text.as_str() {
                    "function" => Tok::Function,
                    "if" => Tok::If,
                    "else" => Tok::Else,
                    "for" => Tok::For,
                    "while" => Tok::While,
                    "in" => Tok::In,
                    "break" => Tok::Break,
                    "next" => Tok::Next,
                    "TRUE" => Tok::True,
                    "FALSE" => Tok::False,
                    "NULL" => Tok::Null,
                    "NA" => Tok::Na,
                    "Inf" => Tok::Inf,
                    "NaN" => Tok::NaN,
                    "..." => Tok::Dots,
                    _ => {
                        if text == "..." {
                            Tok::Dots
                        } else {
                            Tok::Ident(text)
                        }
                    }
                }
            }
            other => return Err(lx.err(&format!("unexpected character {:?}", other as char))),
        };
        out.push(Token { kind, line, col });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<Tok> {
        lex(src).unwrap().into_iter().map(|t| t.kind).collect()
    }

    #[test]
    fn lexes_pipe_and_infix() {
        assert_eq!(
            kinds("lapply(xs, fcn) |> futurize()"),
            vec![
                Tok::Ident("lapply".into()),
                Tok::LParen,
                Tok::Ident("xs".into()),
                Tok::Comma,
                Tok::Ident("fcn".into()),
                Tok::RParen,
                Tok::Pipe,
                Tok::Ident("futurize".into()),
                Tok::LParen,
                Tok::RParen,
            ]
        );
    }

    #[test]
    fn lexes_do_infix() {
        let ks = kinds("foreach(x = xs) %do% { slow_fcn(x) }");
        assert!(ks.contains(&Tok::Infix("%do%".into())));
    }

    #[test]
    fn lexes_numbers() {
        assert_eq!(kinds("42L"), vec![Tok::Int(42)]);
        assert_eq!(kinds("1e3"), vec![Tok::Num(1000.0)]);
        assert_eq!(kinds("2.5"), vec![Tok::Num(2.5)]);
    }

    #[test]
    fn lexes_dotted_idents_and_namespace() {
        assert_eq!(
            kinds("glmnet::cv.glmnet"),
            vec![
                Tok::Ident("glmnet".into()),
                Tok::DoubleColon,
                Tok::Ident("cv.glmnet".into())
            ]
        );
    }

    #[test]
    fn lexes_lambda_and_arrows() {
        let ks = kinds("ys <- \\(x) x + 1");
        assert_eq!(ks[1], Tok::LeftAssign);
        assert_eq!(ks[2], Tok::Backslash);
    }

    #[test]
    fn strips_comments() {
        assert_eq!(kinds("x # comment\n"), vec![Tok::Ident("x".into()), Tok::Newline]);
    }

    #[test]
    fn lexes_strings_with_escapes() {
        assert_eq!(kinds(r#""a\nb""#), vec![Tok::Str("a\nb".into())]);
        assert_eq!(kinds("'sq'"), vec![Tok::Str("sq".into())]);
    }

    #[test]
    fn lexes_double_brackets() {
        assert_eq!(
            kinds("x[[1]]"),
            vec![
                Tok::Ident("x".into()),
                Tok::DoubleLBracket,
                Tok::Num(1.0),
                Tok::DoubleRBracket
            ]
        );
    }
}
