//! Abstract syntax tree for rlite.
//!
//! Expressions are plain data (`Clone + PartialEq + Serialize`), which is
//! what makes the futurize transpiler possible: `futurize()` receives the
//! unevaluated [`Expr`] of its first argument, rewrites it, and evaluates
//! the rewritten tree. Task payloads shipped to parallel workers are also
//! `Expr`s plus a serialized globals environment.

use serde_derive::{Deserialize, Serialize};

use super::intern::Symbol;

/// A call argument: optionally named, as in `f(x, n = 10)`.
#[derive(Clone, Debug, PartialEq, Serialize, Deserialize)]
pub struct Arg {
    pub name: Option<String>,
    pub value: Expr,
}

impl Arg {
    pub fn pos(value: Expr) -> Self {
        Arg { name: None, value }
    }
    pub fn named(name: &str, value: Expr) -> Self {
        Arg { name: Some(name.to_string()), value }
    }
}

/// A formal parameter of a `function(...)` definition. The name is an
/// interned [`Symbol`] so per-call parameter binding is id comparison
/// (it still serializes as the identifier text).
#[derive(Clone, Debug, PartialEq, Serialize, Deserialize)]
pub struct Param {
    pub name: Symbol,
    pub default: Option<Expr>,
}

/// An rlite expression.
#[derive(Clone, Debug, PartialEq, Serialize, Deserialize)]
pub enum Expr {
    /// `NULL`
    Null,
    /// `TRUE` / `FALSE`
    Bool(bool),
    /// Integer literal `42L` (and integer-valued ranges)
    Int(i64),
    /// Numeric literal
    Num(f64),
    /// String literal
    Str(String),
    /// Symbol (variable reference), interned at parse time
    Sym(Symbol),
    /// Namespace access `pkg::name`
    Ns { pkg: String, name: String },
    /// Function call `f(a, b = 1)`. Infix operators, `[`/`[[` indexing and
    /// `%op%` operators are all represented as calls, as in R.
    Call { func: Box<Expr>, args: Vec<Arg> },
    /// `function(x, y = 1) body` or `\(x) body`
    Function { params: Vec<Param>, body: Box<Expr> },
    /// `{ e1; e2; ... }`
    Block(Vec<Expr>),
    /// `if (cond) then else els`
    If { cond: Box<Expr>, then: Box<Expr>, els: Option<Box<Expr>> },
    /// `for (var in seq) body`
    For { var: Symbol, seq: Box<Expr>, body: Box<Expr> },
    /// `while (cond) body`
    While { cond: Box<Expr>, body: Box<Expr> },
    /// `target <- value` (also `=` at statement level and `->` reversed)
    Assign { target: Box<Expr>, value: Box<Expr> },
    /// `target <<- value`: super-assignment into the nearest enclosing
    /// frame that binds `target` (else the global environment).
    SuperAssign { target: Box<Expr>, value: Box<Expr> },
    /// `x[i]` (single-bracket) / `x[[i]]` (double-bracket)
    Index { obj: Box<Expr>, args: Vec<Arg>, double: bool },
    /// `x$name`
    Dollar { obj: Box<Expr>, name: String },
    /// `break`
    Break,
    /// `next`
    Next,
    /// An elided argument slot (empty argument, e.g. `x[ , 1]`)
    Missing,
    /// The `...` symbol forwarded inside a function body
    Dots,
}

impl Expr {
    /// Convenience: build a call to a named function.
    pub fn call(name: &str, args: Vec<Arg>) -> Expr {
        Expr::Call { func: Box::new(Expr::Sym(name.into())), args }
    }

    /// Convenience: build a namespaced call `pkg::name(args)`.
    pub fn ns_call(pkg: &str, name: &str, args: Vec<Arg>) -> Expr {
        Expr::Call {
            func: Box::new(Expr::Ns { pkg: pkg.to_string(), name: name.to_string() }),
            args,
        }
    }

    /// If this expression is a call, return `(head, args)` where `head` is
    /// the textual function name (ignoring namespace qualification).
    pub fn as_call(&self) -> Option<(&Expr, &[Arg])> {
        match self {
            Expr::Call { func, args } => Some((func, args)),
            _ => None,
        }
    }

    /// The called function's bare name, if statically known:
    /// `lapply(...)` -> "lapply", `base::lapply(...)` -> "lapply".
    pub fn call_name(&self) -> Option<&str> {
        match self {
            Expr::Call { func, .. } => match func.as_ref() {
                Expr::Sym(s) => Some(s.as_str()),
                Expr::Ns { name, .. } => Some(name),
                _ => None,
            },
            _ => None,
        }
    }

    /// The explicit namespace qualifier of a call, if present.
    pub fn call_namespace(&self) -> Option<&str> {
        match self {
            Expr::Call { func, .. } => match func.as_ref() {
                Expr::Ns { pkg, .. } => Some(pkg),
                _ => None,
            },
            _ => None,
        }
    }

    /// True for literal leaves (no evaluation effects).
    pub fn is_literal(&self) -> bool {
        matches!(
            self,
            Expr::Null | Expr::Bool(_) | Expr::Int(_) | Expr::Num(_) | Expr::Str(_)
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn call_name_plain_and_namespaced() {
        let e = Expr::call("lapply", vec![Arg::pos(Expr::Sym("xs".into()))]);
        assert_eq!(e.call_name(), Some("lapply"));
        assert_eq!(e.call_namespace(), None);

        let e = Expr::ns_call("purrr", "map", vec![]);
        assert_eq!(e.call_name(), Some("map"));
        assert_eq!(e.call_namespace(), Some("purrr"));
    }

    #[test]
    fn ast_roundtrips_serde() {
        let e = Expr::call(
            "lapply",
            vec![
                Arg::pos(Expr::Sym("xs".into())),
                Arg::pos(Expr::Function {
                    params: vec![Param { name: "x".into(), default: None }],
                    body: Box::new(Expr::call(
                        "^",
                        vec![Arg::pos(Expr::Sym("x".into())), Arg::pos(Expr::Num(2.0))],
                    )),
                }),
            ],
        );
        let json = crate::wire::to_string(&e).unwrap();
        let back: Expr = crate::wire::from_str(&json).unwrap();
        assert_eq!(e, back);
    }
}
