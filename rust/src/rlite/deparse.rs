//! Deparse: render an [`Expr`] back to source text.
//!
//! Used by `futurize(eval = FALSE)` — the paper's introspection hook that
//! returns the transpiled call without evaluating it — and by error
//! messages ("Error in f(x): ...").

use super::ast::{Arg, Expr};

/// Render an expression as (approximately) the source that produced it.
pub fn deparse(e: &Expr) -> String {
    match e {
        Expr::Null => "NULL".into(),
        Expr::Bool(b) => if *b { "TRUE" } else { "FALSE" }.into(),
        Expr::Int(v) => format!("{v}L"),
        Expr::Num(v) => super::value::format_dbl(*v),
        Expr::Str(s) => format!("{s:?}"),
        Expr::Sym(s) => s.to_string(),
        Expr::Ns { pkg, name } => format!("{pkg}::{name}"),
        Expr::Dots => "...".into(),
        Expr::Missing => String::new(),
        Expr::Break => "break".into(),
        Expr::Next => "next".into(),
        Expr::Call { func, args } => deparse_call(func, args),
        Expr::Function { params, body } => {
            let ps = params
                .iter()
                .map(|p| match &p.default {
                    Some(d) => format!("{} = {}", p.name, deparse(d)),
                    None => p.name.to_string(),
                })
                .collect::<Vec<_>>()
                .join(", ");
            format!("function({ps}) {}", deparse(body))
        }
        Expr::Block(stmts) => {
            let inner = stmts.iter().map(deparse).collect::<Vec<_>>().join("; ");
            format!("{{ {inner} }}")
        }
        Expr::If { cond, then, els } => match els {
            Some(e2) => format!("if ({}) {} else {}", deparse(cond), deparse(then), deparse(e2)),
            None => format!("if ({}) {}", deparse(cond), deparse(then)),
        },
        Expr::For { var, seq, body } => {
            format!("for ({var} in {}) {}", deparse(seq), deparse(body))
        }
        Expr::While { cond, body } => format!("while ({}) {}", deparse(cond), deparse(body)),
        Expr::Assign { target, value } => format!("{} <- {}", deparse(target), deparse(value)),
        Expr::SuperAssign { target, value } => {
            format!("{} <<- {}", deparse(target), deparse(value))
        }
        Expr::Index { obj, args, double } => {
            let inner = args.iter().map(deparse_arg).collect::<Vec<_>>().join(", ");
            if *double {
                format!("{}[[{}]]", deparse(obj), inner)
            } else {
                format!("{}[{}]", deparse(obj), inner)
            }
        }
        Expr::Dollar { obj, name } => format!("{}${}", deparse(obj), name),
    }
}

fn deparse_arg(a: &Arg) -> String {
    match &a.name {
        Some(n) => format!("{n} = {}", deparse(&a.value)),
        None => deparse(&a.value),
    }
}

const BINARY_OPS: &[&str] = &[
    "+", "-", "*", "/", "^", "==", "!=", "<", ">", "<=", ">=", "&", "&&", "|", "||", ":",
];

fn deparse_call(func: &Expr, args: &[Arg]) -> String {
    if let Expr::Sym(name) = func {
        // Binary / unary operators print in infix form.
        if BINARY_OPS.contains(&name.as_str()) && args.len() == 2 {
            return format!("{} {} {}", deparse(&args[0].value), name, deparse(&args[1].value));
        }
        if (name == "-" || name == "!" || name == "+") && args.len() == 1 {
            return format!("{name}{}", deparse(&args[0].value));
        }
        if name.as_str().starts_with('%') && name.as_str().ends_with('%') && args.len() == 2 {
            return format!("{} {} {}", deparse(&args[0].value), name, deparse(&args[1].value));
        }
        if name == "(" && args.len() == 1 {
            return format!("({})", deparse(&args[0].value));
        }
    }
    let inner = args.iter().map(deparse_arg).collect::<Vec<_>>().join(", ");
    format!("{}({})", deparse(func), inner)
}

#[cfg(test)]
mod tests {
    use super::super::parse_expr;
    use super::*;

    fn roundtrip(src: &str) -> String {
        deparse(&parse_expr(src).unwrap())
    }

    #[test]
    fn deparses_calls() {
        assert_eq!(roundtrip("lapply(xs, fcn)"), "lapply(xs, fcn)");
        assert_eq!(roundtrip("map(xs, f, n = 10)"), "map(xs, f, n = 10)");
    }

    #[test]
    fn deparses_namespaced() {
        assert_eq!(
            roundtrip("future.apply::future_lapply(xs, fcn)"),
            "future.apply::future_lapply(xs, fcn)"
        );
    }

    #[test]
    fn deparses_infix() {
        assert_eq!(roundtrip("x + y * 2"), "x + y * 2");
        assert_eq!(roundtrip("foreach(x = xs) %do% { f(x) }"), "foreach(x = xs) %do% { f(x) }");
    }

    #[test]
    fn deparses_function() {
        assert_eq!(roundtrip("function(x) x^2"), "function(x) x ^ 2");
    }

    #[test]
    fn pipe_deparses_in_desugared_form() {
        // The pipe desugars at parse time, as in R; deparse shows the call.
        assert_eq!(roundtrip("xs |> f()"), "f(xs)");
    }

    #[test]
    fn reparse_of_deparse_is_stable() {
        for src in [
            "lapply(xs, function(x) x + 1)",
            "if (a > 1) f(a) else g(a)",
            "for (i in 1:10) s <- s + i",
            "x[[2]]",
            "df$col",
        ] {
            let once = roundtrip(src);
            let twice = deparse(&parse_expr(&once).unwrap());
            assert_eq!(once, twice);
        }
    }
}
