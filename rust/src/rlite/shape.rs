//! AST shape analysis for kernel fusion: canonicalization and
//! structural fingerprinting of map-body expressions over interned
//! symbols.
//!
//! The transpile-time recognizer (`transpile::fusion`) pattern-matches
//! closure bodies against a small kernel catalog. This module holds the
//! rlite-side half of that analysis: [`peel`] strips no-op wrappers so
//! equivalent spellings (`{ x * 2 }` vs `x * 2`) normalize to one tree,
//! and [`fingerprint`] renders a body as a compact canonical label —
//! the map element as `.`, resolvable constants as `#`, anything
//! outside the recognizable grammar as `?` — used for trace/bench/test
//! labeling of matched shapes. Both operate on interned [`Symbol`]s, so
//! per-node work is u32 comparison, not string hashing.

use super::ast::Expr;
use super::intern::Symbol;

/// Strip single-expression `{ ... }` blocks: `{ x * 2 }` and `x * 2`
/// evaluate identically, so shape analysis sees one tree for both.
/// Multi-statement blocks are *not* peeled — sequencing is semantics.
pub fn peel(e: &Expr) -> &Expr {
    let mut cur = e;
    while let Expr::Block(v) = cur {
        if v.len() != 1 {
            break;
        }
        cur = &v[0];
    }
    cur
}

/// The callee of a call expression, when it is statically known:
/// `(namespace, name)` for a bare symbol or `pkg::name` head. Computed
/// heads (`(get(f))(x)`) return `None` — they are never fusable.
pub fn callee(func: &Expr) -> Option<(Option<&str>, Symbol)> {
    match func {
        Expr::Sym(s) => Some((None, *s)),
        Expr::Ns { pkg, name } => Some((Some(pkg.as_str()), Symbol::intern(name))),
        _ => None,
    }
}

/// Structural fingerprint of a body: the map element renders as `.`,
/// numeric literals and symbols `resolves` accepts render as `#`, calls
/// render as `name(args)`, and any node outside this grammar as `?`.
/// Total — never fails — so recognizers can label near-misses too.
pub fn fingerprint(e: &Expr, elem: Symbol, resolves: &dyn Fn(Symbol) -> bool) -> String {
    let mut out = String::new();
    render(peel(e), elem, resolves, &mut out);
    out
}

fn render(e: &Expr, elem: Symbol, resolves: &dyn Fn(Symbol) -> bool, out: &mut String) {
    match peel(e) {
        Expr::Num(_) | Expr::Int(_) => out.push('#'),
        Expr::Sym(s) if *s == elem => out.push('.'),
        Expr::Sym(s) if resolves(*s) => out.push('#'),
        Expr::Dollar { obj, name } => match peel(obj) {
            Expr::Sym(s) if resolves(*s) => {
                out.push('#');
                out.push('$');
                out.push_str(name);
            }
            _ => out.push('?'),
        },
        Expr::Call { func, args } => match callee(func) {
            Some((_, name)) => {
                out.push_str(name.as_str());
                out.push('(');
                for (i, a) in args.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if a.name.is_some() {
                        out.push('?');
                    } else {
                        render(&a.value, elem, resolves, out);
                    }
                }
                out.push(')');
            }
            None => out.push('?'),
        },
        _ => out.push('?'),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rlite::parse_expr;

    fn fp(src: &str, consts: &[&str]) -> String {
        let e = parse_expr(src).unwrap();
        let elem = Symbol::intern("x");
        let consts: Vec<Symbol> = consts.iter().map(|s| Symbol::intern(s)).collect();
        fingerprint(&e, elem, &|s| consts.contains(&s))
    }

    #[test]
    fn peel_unwraps_single_expression_blocks() {
        let wrapped = parse_expr("{ x * 2 }").unwrap();
        let bare = parse_expr("x * 2").unwrap();
        assert_eq!(peel(&wrapped), &bare);
        // Nested single-expression blocks peel all the way down.
        let nested = parse_expr("{ { x * 2 } }").unwrap();
        assert_eq!(peel(&nested), &bare);
        // Multi-statement blocks stay intact.
        let multi = parse_expr("{ y <- 1\nx * 2 }").unwrap();
        assert!(matches!(peel(&multi), Expr::Block(v) if v.len() == 2));
    }

    #[test]
    fn fingerprint_canonical_forms() {
        assert_eq!(fp("x * 2 + 1", &[]), "+(*(.,#),#)");
        assert_eq!(fp("{ x * 2 + 1 }", &[]), "+(*(.,#),#)");
        assert_eq!(fp("3 * x * x + 2 * x + 1", &[]), "+(+(*(*(#,.),.),*(#,.)),#)");
        assert_eq!(fp("a * x", &["a"]), "*(#,.)");
        // Unresolvable free symbols and non-catalog nodes degrade to `?`.
        assert_eq!(fp("a * x", &[]), "*(?,.)");
        assert_eq!(fp("if (x > 0) x else 0", &[]), "?");
        assert_eq!(fp("sum(d$x * x)", &["d"]), "sum(*(#$x,.))");
    }

    #[test]
    fn fingerprint_is_total_on_weird_shapes() {
        assert_eq!(fp("x[[1]](2)", &[]), "?");
        assert_eq!(fp("f(a = 1)", &[]), "f(?)");
    }
}
