//! Runtime values for rlite.
//!
//! The model mirrors the R types the paper's examples need: typed vectors
//! with optional names, heterogeneous lists (also used for data.frames),
//! closures, builtins, and condition objects. Scalars are length-1
//! vectors, as in R.
//!
//! Vector payloads are **copy-on-write**: `RVec<T>` holds its elements
//! behind a shared `Rc<Vec<T>>`, so cloning a value (environment lookup,
//! argument passing, `y <- x`) is a refcount bump, while mutation goes
//! through [`RVec::vals_mut`] (`Rc::make_mut`), which copies the buffer
//! only when it is actually shared. That is exactly R's copy-on-modify
//! semantics, made O(1) on the read side.

use std::cell::RefCell;
use std::fmt;
use std::rc::Rc;

use super::ast::{Expr, Param};
use super::builtins::BuiltinId;
use super::conditions::RCondition;
use super::env::EnvRef;

/// A typed vector with optional element names. The payload is a shared
/// copy-on-write buffer; names stay eagerly owned (they are rare and
/// small on the hot paths).
#[derive(Clone, Debug, PartialEq, Default)]
pub struct RVec<T> {
    pub vals: Rc<Vec<T>>,
    pub names: Option<Vec<String>>,
}

impl<T> RVec<T> {
    pub fn plain(vals: Vec<T>) -> Self {
        RVec { vals: Rc::new(vals), names: None }
    }
    pub fn named(vals: Vec<T>, names: Vec<String>) -> Self {
        RVec { vals: Rc::new(vals), names: Some(names) }
    }
    pub fn with_names(vals: Vec<T>, names: Option<Vec<String>>) -> Self {
        RVec { vals: Rc::new(vals), names }
    }
    /// Wrap an already-shared buffer without copying it.
    pub fn from_shared(vals: Rc<Vec<T>>, names: Option<Vec<String>>) -> Self {
        RVec { vals, names }
    }
    pub fn len(&self) -> usize {
        self.vals.len()
    }
    pub fn is_empty(&self) -> bool {
        self.vals.is_empty()
    }
    /// Do two vectors alias the same underlying buffer? (COW test hook.)
    pub fn shares_buffer(&self, other: &RVec<T>) -> bool {
        Rc::ptr_eq(&self.vals, &other.vals)
    }
}

impl<T: Clone> RVec<T> {
    /// Mutable access to the payload, copying it first iff shared —
    /// R's copy-on-modify.
    pub fn vals_mut(&mut self) -> &mut Vec<T> {
        Rc::make_mut(&mut self.vals)
    }
    /// Take the payload out, moving the buffer when uniquely owned and
    /// cloning otherwise.
    pub fn take_vals(self) -> Vec<T> {
        Rc::try_unwrap(self.vals).unwrap_or_else(|rc| (*rc).clone())
    }
    /// Decompose into (payload, names), moving both when possible —
    /// the payload moves iff uniquely owned; names always move.
    pub fn into_parts(self) -> (Vec<T>, Option<Vec<String>>) {
        let RVec { vals, names } = self;
        (Rc::try_unwrap(vals).unwrap_or_else(|rc| (*rc).clone()), names)
    }
}

/// A heterogeneous list, optionally named. Data-frame-like values are
/// lists of equal-length column vectors with names plus the
/// `"data.frame"` class attribute.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct RList {
    pub vals: Vec<RVal>,
    pub names: Option<Vec<String>>,
    /// S3-style class attribute (e.g. `"data.frame"`, `"boot"`).
    pub class: Option<String>,
}

impl RList {
    pub fn plain(vals: Vec<RVal>) -> Self {
        RList { vals, names: None, class: None }
    }
    pub fn named(vals: Vec<RVal>, names: Vec<String>) -> Self {
        RList { vals, names: Some(names), class: None }
    }
    pub fn len(&self) -> usize {
        self.vals.len()
    }
    pub fn is_empty(&self) -> bool {
        self.vals.is_empty()
    }
    pub fn get(&self, name: &str) -> Option<&RVal> {
        let names = self.names.as_ref()?;
        let idx = names.iter().position(|n| n == name)?;
        self.vals.get(idx)
    }
    /// Set (or append) a named element with a single name scan; a
    /// freshly materialized names vector (all empty) skips the scan.
    pub fn set(&mut self, name: &str, val: RVal) {
        let fresh = self.names.is_none();
        if fresh {
            self.names = Some(vec![String::new(); self.vals.len()]);
        }
        let names = self.names.as_mut().unwrap();
        let found = if fresh { None } else { names.iter().position(|n| n == name) };
        match found {
            Some(idx) => self.vals[idx] = val,
            None => {
                names.push(name.to_string());
                self.vals.push(val);
            }
        }
    }
}

/// A user-defined closure: formals + body + defining environment.
#[derive(Clone, Debug)]
pub struct RClosure {
    pub params: Vec<Param>,
    pub body: Expr,
    pub env: EnvRef,
}

impl PartialEq for RClosure {
    fn eq(&self, other: &Self) -> bool {
        self.params == other.params && self.body == other.body
    }
}

/// An rlite runtime value.
#[derive(Clone, Debug)]
pub enum RVal {
    Null,
    Lgl(RVec<bool>),
    Int(RVec<i64>),
    Dbl(RVec<f64>),
    Chr(RVec<String>),
    List(RList),
    Closure(Rc<RClosure>),
    /// A builtin function, pre-resolved to its registry slot — call
    /// dispatch is an array index, not a string lookup.
    Builtin(BuiltinId),
    /// A condition object (error/warning/message/custom), first-class so
    /// `tryCatch(..., error = function(e) e)` can return it.
    Cond(Box<RCondition>),
    /// An environment as a value (used by `local()`, `environment()`).
    Env(EnvRef),
}

impl PartialEq for RVal {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (RVal::Null, RVal::Null) => true,
            (RVal::Lgl(a), RVal::Lgl(b)) => a == b,
            (RVal::Int(a), RVal::Int(b)) => a == b,
            (RVal::Dbl(a), RVal::Dbl(b)) => a == b,
            (RVal::Chr(a), RVal::Chr(b)) => a == b,
            (RVal::List(a), RVal::List(b)) => a == b,
            (RVal::Closure(a), RVal::Closure(b)) => a == b,
            (RVal::Builtin(a), RVal::Builtin(b)) => a == b,
            (RVal::Cond(a), RVal::Cond(b)) => a == b,
            // Environments compare by identity, as in R.
            (RVal::Env(a), RVal::Env(b)) => std::rc::Rc::ptr_eq(a, b),
            _ => false,
        }
    }
}

impl RVal {
    // ---- constructors ---------------------------------------------------

    pub fn scalar_dbl(v: f64) -> RVal {
        RVal::Dbl(RVec::plain(vec![v]))
    }
    pub fn scalar_int(v: i64) -> RVal {
        RVal::Int(RVec::plain(vec![v]))
    }
    pub fn scalar_bool(v: bool) -> RVal {
        RVal::Lgl(RVec::plain(vec![v]))
    }
    pub fn scalar_str(v: impl Into<String>) -> RVal {
        RVal::Chr(RVec::plain(vec![v.into()]))
    }
    pub fn dbl(vals: Vec<f64>) -> RVal {
        RVal::Dbl(RVec::plain(vals))
    }
    pub fn int(vals: Vec<i64>) -> RVal {
        RVal::Int(RVec::plain(vals))
    }
    pub fn chr(vals: Vec<String>) -> RVal {
        RVal::Chr(RVec::plain(vals))
    }
    pub fn lgl(vals: Vec<bool>) -> RVal {
        RVal::Lgl(RVec::plain(vals))
    }
    pub fn list(vals: Vec<RVal>) -> RVal {
        RVal::List(RList::plain(vals))
    }

    // ---- inspection ------------------------------------------------------

    /// `length()` semantics.
    pub fn len(&self) -> usize {
        match self {
            RVal::Null => 0,
            RVal::Lgl(v) => v.len(),
            RVal::Int(v) => v.len(),
            RVal::Dbl(v) => v.len(),
            RVal::Chr(v) => v.len(),
            RVal::List(l) => l.len(),
            _ => 1,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, RVal::Null)
    }

    pub fn is_function(&self) -> bool {
        matches!(self, RVal::Closure(_) | RVal::Builtin(_))
    }

    /// The R `class()` of this value.
    pub fn class(&self) -> &str {
        match self {
            RVal::Null => "NULL",
            RVal::Lgl(_) => "logical",
            RVal::Int(_) => "integer",
            RVal::Dbl(_) => "numeric",
            RVal::Chr(_) => "character",
            RVal::List(l) => l.class.as_deref().unwrap_or("list"),
            RVal::Closure(_) | RVal::Builtin(_) => "function",
            RVal::Cond(c) => c.primary_class(),
            RVal::Env(_) => "environment",
        }
    }

    /// Names attribute, if any.
    pub fn names(&self) -> Option<&[String]> {
        match self {
            RVal::Lgl(v) => v.names.as_deref(),
            RVal::Int(v) => v.names.as_deref(),
            RVal::Dbl(v) => v.names.as_deref(),
            RVal::Chr(v) => v.names.as_deref(),
            RVal::List(l) => l.names.as_deref(),
            _ => None,
        }
    }

    pub fn set_names(&mut self, names: Option<Vec<String>>) {
        match self {
            RVal::Lgl(v) => v.names = names,
            RVal::Int(v) => v.names = names,
            RVal::Dbl(v) => v.names = names,
            RVal::Chr(v) => v.names = names,
            RVal::List(l) => l.names = names,
            _ => {}
        }
    }

    // ---- coercions -------------------------------------------------------

    /// Coerce to a double vector (`as.numeric` semantics for the types we
    /// support). Lists of length-1 numerics also flatten, supporting
    /// `sapply`-style simplification.
    pub fn as_dbl_vec(&self) -> Result<Vec<f64>, String> {
        match self {
            RVal::Null => Ok(vec![]),
            RVal::Dbl(v) => Ok(v.vals.to_vec()),
            RVal::Int(v) => Ok(v.vals.iter().map(|&x| x as f64).collect()),
            RVal::Lgl(v) => Ok(v.vals.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect()),
            RVal::List(l) => {
                let mut out = Vec::with_capacity(l.len());
                for v in &l.vals {
                    let d = v.as_dbl_vec()?;
                    out.extend(d);
                }
                Ok(out)
            }
            other => Err(format!("cannot coerce {} to numeric", other.class())),
        }
    }

    /// Borrowed view of a double payload, when the value already is one
    /// (the zero-copy fast path of vectorized arithmetic).
    pub fn as_dbl_slice(&self) -> Option<&[f64]> {
        match self {
            RVal::Dbl(v) => Some(&v.vals),
            _ => None,
        }
    }

    /// First element as f64 (scalar contexts).
    pub fn as_f64(&self) -> Result<f64, String> {
        match self {
            RVal::Dbl(v) if !v.is_empty() => Ok(v.vals[0]),
            RVal::Int(v) if !v.is_empty() => Ok(v.vals[0] as f64),
            RVal::Lgl(v) if !v.is_empty() => Ok(if v.vals[0] { 1.0 } else { 0.0 }),
            other => Err(format!("expected a numeric scalar, got {}", other.class())),
        }
    }

    pub fn as_usize(&self) -> Result<usize, String> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 && f.fract().abs() > 1e-9 {
            return Err(format!("expected a non-negative integer, got {f}"));
        }
        Ok(f as usize)
    }

    pub fn as_i64(&self) -> Result<i64, String> {
        Ok(self.as_f64()? as i64)
    }

    pub fn as_bool(&self) -> Result<bool, String> {
        match self {
            RVal::Lgl(v) if !v.is_empty() => Ok(v.vals[0]),
            RVal::Int(v) if !v.is_empty() => Ok(v.vals[0] != 0),
            RVal::Dbl(v) if !v.is_empty() => Ok(v.vals[0] != 0.0),
            other => Err(format!("expected a logical scalar, got {}", other.class())),
        }
    }

    pub fn as_str(&self) -> Result<String, String> {
        match self {
            RVal::Chr(v) if !v.is_empty() => Ok(v.vals[0].clone()),
            other => Err(format!("expected a character scalar, got {}", other.class())),
        }
    }

    pub fn as_str_vec(&self) -> Result<Vec<String>, String> {
        match self {
            RVal::Null => Ok(vec![]),
            RVal::Chr(v) => Ok(v.vals.to_vec()),
            RVal::Dbl(v) => Ok(v.vals.iter().map(|x| format_dbl(*x)).collect()),
            RVal::Int(v) => Ok(v.vals.iter().map(|x| x.to_string()).collect()),
            RVal::Lgl(v) => {
                Ok(v.vals.iter().map(|b| if *b { "TRUE" } else { "FALSE" }.to_string()).collect())
            }
            other => Err(format!("cannot coerce {} to character", other.class())),
        }
    }

    /// Split into per-element values for iteration: a list iterates its
    /// elements; an atomic vector iterates scalars; a data.frame iterates
    /// its *columns* (as R's `lapply` over a data.frame does).
    pub fn iter_elements(&self) -> Vec<RVal> {
        match self {
            RVal::Null => vec![],
            RVal::Lgl(v) => v.vals.iter().map(|&b| RVal::scalar_bool(b)).collect(),
            RVal::Int(v) => v.vals.iter().map(|&x| RVal::scalar_int(x)).collect(),
            RVal::Dbl(v) => v.vals.iter().map(|&x| RVal::scalar_dbl(x)).collect(),
            RVal::Chr(v) => v.vals.iter().map(|s| RVal::scalar_str(s.clone())).collect(),
            RVal::List(l) => l.vals.clone(),
            other => vec![other.clone()],
        }
    }

    /// Element names for iteration (used by `imap()` and friends).
    pub fn element_names(&self) -> Option<Vec<String>> {
        self.names().map(|n| n.to_vec())
    }

    /// Simplify a list to an atomic vector if every element is an atomic
    /// scalar of a common type (the `sapply`/`map_dbl` rule). Equal-length
    /// numeric vectors simplify to one column-major vector (R's
    /// matrix-result rule for `sapply`/`replicate`, flattened — our matrix
    /// model is a flat column-major vector).
    pub fn simplify(list: Vec<RVal>, names: Option<Vec<String>>) -> RVal {
        let all_scalar_num = list.iter().all(|v| {
            matches!(v, RVal::Dbl(x) if x.len() == 1) || matches!(v, RVal::Int(x) if x.len() == 1)
        });
        if !list.is_empty() && all_scalar_num {
            let vals: Vec<f64> = list.iter().map(|v| v.as_f64().unwrap()).collect();
            return RVal::Dbl(RVec::with_names(vals, names));
        }
        // Equal-length (>1) numeric columns → flat column-major vector.
        let common_len = match list.first() {
            Some(RVal::Dbl(x)) if x.len() > 1 => Some(x.len()),
            Some(RVal::Int(x)) if x.len() > 1 => Some(x.len()),
            _ => None,
        };
        if let Some(k) = common_len {
            let all_cols = list.iter().all(|v| {
                matches!(v, RVal::Dbl(x) if x.len() == k)
                    || matches!(v, RVal::Int(x) if x.len() == k)
            });
            if all_cols {
                let mut vals = Vec::with_capacity(k * list.len());
                for v in &list {
                    vals.extend(v.as_dbl_vec().unwrap());
                }
                return RVal::dbl(vals);
            }
        }
        let all_scalar_lgl = list.iter().all(|v| matches!(v, RVal::Lgl(x) if x.len() == 1));
        if !list.is_empty() && all_scalar_lgl {
            let vals: Vec<bool> = list.iter().map(|v| v.as_bool().unwrap()).collect();
            return RVal::Lgl(RVec::with_names(vals, names));
        }
        let all_scalar_chr = list.iter().all(|v| matches!(v, RVal::Chr(x) if x.len() == 1));
        if !list.is_empty() && all_scalar_chr {
            let vals: Vec<String> = list.iter().map(|v| v.as_str().unwrap()).collect();
            return RVal::Chr(RVec::with_names(vals, names));
        }
        RVal::List(RList { vals: list, names, class: None })
    }
}

/// Format a double the way R prints it in vectors (compact).
pub fn format_dbl(x: f64) -> String {
    if x.is_nan() {
        "NaN".into()
    } else if x.is_infinite() {
        if x > 0.0 { "Inf".into() } else { "-Inf".into() }
    } else if x == x.trunc() && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        let s = format!("{:.6}", x);
        s.trim_end_matches('0').trim_end_matches('.').to_string()
    }
}

impl fmt::Display for RVal {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RVal::Null => write!(f, "NULL"),
            RVal::Dbl(v) => write!(
                f,
                "[1] {}",
                v.vals.iter().map(|x| format_dbl(*x)).collect::<Vec<_>>().join(" ")
            ),
            RVal::Int(v) => write!(
                f,
                "[1] {}",
                v.vals.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(" ")
            ),
            RVal::Lgl(v) => write!(
                f,
                "[1] {}",
                v.vals
                    .iter()
                    .map(|b| if *b { "TRUE" } else { "FALSE" })
                    .collect::<Vec<_>>()
                    .join(" ")
            ),
            RVal::Chr(v) => write!(
                f,
                "[1] {}",
                v.vals.iter().map(|s| format!("{s:?}")).collect::<Vec<_>>().join(" ")
            ),
            RVal::List(l) => {
                write!(f, "list of {}", l.len())?;
                if let Some(cls) = &l.class {
                    write!(f, " <{cls}>")?;
                }
                Ok(())
            }
            RVal::Closure(_) => write!(f, "<closure>"),
            RVal::Builtin(id) => match super::builtins::builtin_by_id(*id) {
                Some(d) => write!(f, "<builtin: {}>", d.key()),
                None => write!(f, "<builtin: #{id}>"),
            },
            RVal::Cond(c) => write!(f, "<condition: {}>", c.message),
            RVal::Env(_) => write!(f, "<environment>"),
        }
    }
}

/// Shared mutable cell used for environments-as-values.
pub type Cell<T> = Rc<RefCell<T>>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simplify_scalars_to_dbl() {
        let v = RVal::simplify(vec![RVal::scalar_dbl(1.0), RVal::scalar_int(2)], None);
        assert_eq!(v, RVal::dbl(vec![1.0, 2.0]));
    }

    #[test]
    fn simplify_keeps_list_when_mixed() {
        let v = RVal::simplify(vec![RVal::scalar_dbl(1.0), RVal::dbl(vec![1.0, 2.0])], None);
        assert!(matches!(v, RVal::List(_)));
    }

    #[test]
    fn iter_elements_atomic() {
        let v = RVal::int(vec![1, 2, 3]);
        assert_eq!(v.iter_elements().len(), 3);
    }

    #[test]
    fn named_list_get_set() {
        let mut l = RList::named(vec![RVal::scalar_dbl(1.0)], vec!["a".into()]);
        l.set("b", RVal::scalar_dbl(2.0));
        assert_eq!(l.get("b"), Some(&RVal::scalar_dbl(2.0)));
        l.set("a", RVal::scalar_dbl(9.0));
        assert_eq!(l.get("a"), Some(&RVal::scalar_dbl(9.0)));
        assert_eq!(l.len(), 2);
    }

    #[test]
    fn rlist_set_on_unnamed_list_appends_without_scan() {
        let mut l = RList::plain(vec![RVal::scalar_dbl(1.0), RVal::scalar_dbl(2.0)]);
        l.set("k", RVal::scalar_dbl(3.0));
        assert_eq!(l.len(), 3);
        assert_eq!(l.names.as_ref().unwrap(), &["", "", "k"]);
        assert_eq!(l.get("k"), Some(&RVal::scalar_dbl(3.0)));
        // Updating the same key replaces in place, no duplicate entry.
        l.set("k", RVal::scalar_dbl(4.0));
        assert_eq!(l.len(), 3);
        assert_eq!(l.get("k"), Some(&RVal::scalar_dbl(4.0)));
    }

    #[test]
    fn class_names() {
        assert_eq!(RVal::scalar_dbl(1.0).class(), "numeric");
        assert_eq!(RVal::list(vec![]).class(), "list");
        let mut l = RList::plain(vec![]);
        l.class = Some("data.frame".into());
        assert_eq!(RVal::List(l).class(), "data.frame");
    }

    #[test]
    fn format_dbl_compact() {
        assert_eq!(format_dbl(2.0), "2");
        assert_eq!(format_dbl(1.5), "1.5");
        assert_eq!(format_dbl(1.414214), "1.414214");
    }

    #[test]
    fn clone_shares_buffer_until_write() {
        let a = RVec::plain(vec![1.0, 2.0, 3.0]);
        let mut b = a.clone();
        assert!(a.shares_buffer(&b));
        b.vals_mut()[0] = 99.0;
        assert!(!a.shares_buffer(&b), "write must detach the shared buffer");
        assert_eq!(a.vals[0], 1.0);
        assert_eq!(b.vals[0], 99.0);
    }

    #[test]
    fn take_vals_moves_when_unique() {
        let a = RVec::plain(vec![1, 2, 3]);
        let ptr = a.vals.as_ptr();
        let v = a.take_vals();
        assert_eq!(v.as_ptr(), ptr, "unique buffer must move, not copy");
        let b = RVec::plain(vec![4, 5]);
        let _keep = b.clone();
        let w = b.take_vals();
        assert_eq!(w, vec![4, 5]);
    }
}
