//! Wire format for shipping values (including closures) to worker
//! processes.
//!
//! [`RVal`] is not directly serializable because closures hold live
//! environment references. Following the future framework's semantics,
//! closures cross the process boundary *by value*: we statically identify
//! the free variables of the closure body and snapshot their current
//! values (recursively). This is exactly what `future()` does when it
//! exports globals to a PSOCK worker.

use serde_derive::{Deserialize, Serialize};

use super::ast::{Expr, Param};
use super::conditions::RCondition;
use super::env::{self, Env, EnvRef};
use super::value::{RClosure, RList, RVal, RVec};
use crate::globals;

/// Serializable mirror of [`RVal`].
#[derive(Clone, Debug, PartialEq, Serialize, Deserialize)]
pub enum WireVal {
    Null,
    Lgl(Vec<bool>, Option<Vec<String>>),
    Int(Vec<i64>, Option<Vec<String>>),
    Dbl(Vec<f64>, Option<Vec<String>>),
    Chr(Vec<String>, Option<Vec<String>>),
    List(Vec<WireVal>, Option<Vec<String>>, Option<String>),
    Closure { params: Vec<Param>, body: Expr, captured: Vec<(String, WireVal)> },
    Builtin(String),
    Cond(RCondition),
}

impl WireVal {
    /// Rough serialized footprint (bytes), for export-size accounting.
    pub fn approx_size(&self) -> usize {
        match self {
            WireVal::Null => 4,
            WireVal::Lgl(v, _) => v.len() + 8,
            WireVal::Int(v, _) => v.len() * 8 + 8,
            WireVal::Dbl(v, _) => v.len() * 8 + 8,
            WireVal::Chr(v, _) => v.iter().map(|s| s.len() + 8).sum::<usize>() + 8,
            WireVal::List(v, _, _) => v.iter().map(|x| x.approx_size()).sum::<usize>() + 16,
            WireVal::Closure { captured, .. } => {
                256 + captured.iter().map(|(n, v)| n.len() + v.approx_size()).sum::<usize>()
            }
            WireVal::Builtin(n) => n.len() + 8,
            WireVal::Cond(c) => c.message.len() + 64,
        }
    }
}

/// Convert a value to wire form. Closures capture their free variables by
/// value; environments and other live handles are rejected (they cannot
/// meaningfully cross a process boundary — same restriction as R).
pub fn to_wire(v: &RVal) -> Result<WireVal, String> {
    match v {
        RVal::Null => Ok(WireVal::Null),
        RVal::Lgl(x) => Ok(WireVal::Lgl(x.vals.clone(), x.names.clone())),
        RVal::Int(x) => Ok(WireVal::Int(x.vals.clone(), x.names.clone())),
        RVal::Dbl(x) => Ok(WireVal::Dbl(x.vals.clone(), x.names.clone())),
        RVal::Chr(x) => Ok(WireVal::Chr(x.vals.clone(), x.names.clone())),
        RVal::List(l) => {
            let vals: Result<Vec<WireVal>, String> = l.vals.iter().map(to_wire).collect();
            Ok(WireVal::List(vals?, l.names.clone(), l.class.clone()))
        }
        RVal::Builtin(key) => Ok(WireVal::Builtin(key.clone())),
        RVal::Cond(c) => Ok(WireVal::Cond((**c).clone())),
        RVal::Closure(c) => {
            let mut captured = Vec::new();
            // Snapshot free variables of the body (minus the params).
            let body_fn = Expr::Function {
                params: c.params.clone(),
                body: Box::new(c.body.clone()),
            };
            for name in globals::free_variables(&body_fn) {
                if let Some(val) = env::lookup(&c.env, &name) {
                    if matches!(val, RVal::Builtin(_)) {
                        continue;
                    }
                    captured.push((name.clone(), to_wire(&val)?));
                }
                // Builtins and not-found symbols resolve on the worker.
            }
            Ok(WireVal::Closure { params: c.params.clone(), body: c.body.clone(), captured })
        }
        RVal::Env(_) => Err("cannot serialize an environment across processes".into()),
    }
}

/// Reconstruct a value on the worker side. Closures are re-rooted on a
/// fresh environment seeded with their captured variables, whose parent
/// is `base_env` (the worker's global environment).
pub fn from_wire(w: &WireVal, base_env: &EnvRef) -> RVal {
    match w {
        WireVal::Null => RVal::Null,
        WireVal::Lgl(v, n) => RVal::Lgl(RVec { vals: v.clone(), names: n.clone() }),
        WireVal::Int(v, n) => RVal::Int(RVec { vals: v.clone(), names: n.clone() }),
        WireVal::Dbl(v, n) => RVal::Dbl(RVec { vals: v.clone(), names: n.clone() }),
        WireVal::Chr(v, n) => RVal::Chr(RVec { vals: v.clone(), names: n.clone() }),
        WireVal::List(v, n, class) => RVal::List(RList {
            vals: v.iter().map(|x| from_wire(x, base_env)).collect(),
            names: n.clone(),
            class: class.clone(),
        }),
        WireVal::Builtin(key) => RVal::Builtin(key.clone()),
        WireVal::Cond(c) => RVal::Cond(Box::new(c.clone())),
        WireVal::Closure { params, body, captured } => {
            let env = Env::child_of(base_env);
            for (name, val) in captured {
                env::define(&env, name, from_wire(val, base_env));
            }
            RVal::Closure(std::rc::Rc::new(RClosure {
                params: params.clone(),
                body: body.clone(),
                env,
            }))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rlite::eval::Interp;
    use crate::rlite::env::define;

    #[test]
    fn atomic_roundtrip() {
        let v = RVal::dbl(vec![1.0, 2.0]);
        let w = to_wire(&v).unwrap();
        let base = Env::new_ref();
        assert_eq!(from_wire(&w, &base), v);
    }

    #[test]
    fn closure_captures_free_vars_by_value() {
        let mut i = Interp::new();
        i.eval_program("a <- 10\nf <- function(x) x + a").unwrap();
        let f = env::lookup(&i.global, "f").unwrap();
        let w = to_wire(&f).unwrap();
        // Mutate `a` after capture: the wire copy must keep the old value.
        i.eval_program("a <- 999").unwrap();
        let mut worker = Interp::new();
        let g = from_wire(&w, &worker.global);
        let genv = worker.global.clone();
        define(&genv, "g", g);
        let r = worker.eval_program("g(5)").unwrap();
        assert_eq!(r, RVal::scalar_dbl(15.0));
    }

    #[test]
    fn nested_closure_capture() {
        let mut i = Interp::new();
        i.eval_program("b <- 2\ninner <- function(y) y * b\nf <- function(x) inner(x) + 1")
            .unwrap();
        let f = env::lookup(&i.global, "f").unwrap();
        let w = to_wire(&f).unwrap();
        let mut worker = Interp::new();
        let g = from_wire(&w, &worker.global);
        define(&worker.global.clone(), "g", g);
        assert_eq!(worker.eval_program("g(4)").unwrap(), RVal::scalar_dbl(9.0));
    }

    #[test]
    fn env_is_rejected() {
        let env = Env::new_ref();
        assert!(to_wire(&RVal::Env(env)).is_err());
    }

    #[test]
    fn json_roundtrip_of_wire() {
        let w = WireVal::List(
            vec![WireVal::Dbl(vec![1.0], None), WireVal::Chr(vec!["a".into()], None)],
            Some(vec!["x".into(), "y".into()]),
            None,
        );
        let s = crate::wire::to_string(&w).unwrap();
        let back: WireVal = crate::wire::from_str(&s).unwrap();
        assert_eq!(w, back);
    }
}
