//! Wire format for shipping values (including closures) to worker
//! processes.
//!
//! [`RVal`] is not directly serializable because closures hold live
//! environment references. Following the future framework's semantics,
//! closures cross the process boundary *by value*: we statically identify
//! the free variables of the closure body and snapshot their current
//! values (recursively). This is exactly what `future()` does when it
//! exports globals to a PSOCK worker.

use std::sync::Arc;

use serde_derive::{Deserialize, Serialize};

use super::ast::{Expr, Param};
use super::conditions::RCondition;
use super::env::{self, Env, EnvRef};
use super::value::{RClosure, RList, RVal, RVec};
use crate::globals;
use crate::wire::bin::{uvarint_len, zigzag};

/// Serializable mirror of [`RVal`].
#[derive(Clone, Debug, PartialEq, Serialize, Deserialize)]
pub enum WireVal {
    Null,
    Lgl(Vec<bool>, Option<Vec<String>>),
    Int(Vec<i64>, Option<Vec<String>>),
    Dbl(Vec<f64>, Option<Vec<String>>),
    Chr(Vec<String>, Option<Vec<String>>),
    List(Vec<WireVal>, Option<Vec<String>>, Option<String>),
    Closure { params: Vec<Param>, body: Expr, captured: Vec<(String, WireVal)> },
    Builtin(String),
    Cond(RCondition),
}

/// Binary-codec size of an encoded string: varint length + UTF-8 bytes.
fn str_size(s: &str) -> usize {
    uvarint_len(s.len() as u64) + s.len()
}

/// Binary-codec size of an `Option<Vec<String>>` names attribute.
fn names_size(names: &Option<Vec<String>>) -> usize {
    match names {
        None => 1,
        Some(v) => {
            1 + uvarint_len(v.len() as u64) + v.iter().map(|s| str_size(s)).sum::<usize>()
        }
    }
}

impl WireVal {
    /// Serialized footprint in bytes under the default binary codec
    /// ([`crate::wire::bin`]), used for export-size accounting and the
    /// dispatch core's byte budgeting. Exact for data variants (the
    /// formulas mirror the codec: variant tag + varint length prefix +
    /// little-endian/varint elements + names); `Closure` bodies and
    /// `Cond` payloads are estimated (an exact answer would require
    /// encoding the AST). A regression test in `tests/wire_codec.rs`
    /// pins this against real encoded lengths.
    pub fn approx_size(&self) -> usize {
        match self {
            WireVal::Null => 1,
            WireVal::Lgl(v, n) => 1 + uvarint_len(v.len() as u64) + v.len() + names_size(n),
            WireVal::Int(v, n) => {
                1 + uvarint_len(v.len() as u64)
                    + v.iter().map(|&x| uvarint_len(zigzag(x))).sum::<usize>()
                    + names_size(n)
            }
            WireVal::Dbl(v, n) => 1 + uvarint_len(v.len() as u64) + v.len() * 8 + names_size(n),
            WireVal::Chr(v, n) => {
                1 + uvarint_len(v.len() as u64)
                    + v.iter().map(|s| str_size(s)).sum::<usize>()
                    + names_size(n)
            }
            WireVal::List(v, n, class) => {
                1 + uvarint_len(v.len() as u64)
                    + v.iter().map(|x| x.approx_size()).sum::<usize>()
                    + names_size(n)
                    + match class {
                        None => 1,
                        Some(c) => 1 + str_size(c),
                    }
            }
            WireVal::Closure { params, body, captured } => {
                // The body estimate leans on deparse: rlite source text
                // and the binary AST encoding are within a small factor
                // of each other.
                1 + uvarint_len(params.len() as u64)
                    + params.iter().map(|p| 8 + p.name.as_str().len()).sum::<usize>()
                    + super::deparse::deparse(body).len()
                    + uvarint_len(captured.len() as u64)
                    + captured
                        .iter()
                        .map(|(n, v)| str_size(n) + v.approx_size())
                        .sum::<usize>()
            }
            WireVal::Builtin(n) => 1 + str_size(n),
            WireVal::Cond(c) => {
                16 + c.message.len() + c.classes.iter().map(|s| str_size(s)).sum::<usize>()
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Content digests (the data-plane cache's addressing scheme)
// ---------------------------------------------------------------------------

/// FNV-1a 64-bit, rolled by hand so digesting stays dependency-free.
struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    fn bytes(&mut self, bs: &[u8]) {
        for &b in bs {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    fn u64(&mut self, x: u64) {
        self.bytes(&x.to_le_bytes());
    }

    fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.bytes(s.as_bytes());
    }

    fn names(&mut self, names: &Option<Vec<String>>) {
        match names {
            None => self.u64(0),
            Some(v) => {
                self.u64(1 + v.len() as u64);
                for s in v {
                    self.str(s);
                }
            }
        }
    }

    fn val(&mut self, v: &WireVal) {
        match v {
            WireVal::Null => self.u64(0),
            WireVal::Lgl(v, n) => {
                self.u64(1);
                self.u64(v.len() as u64);
                for &b in v {
                    self.bytes(&[b as u8]);
                }
                self.names(n);
            }
            WireVal::Int(v, n) => {
                self.u64(2);
                self.u64(v.len() as u64);
                for &x in v {
                    self.bytes(&x.to_le_bytes());
                }
                self.names(n);
            }
            WireVal::Dbl(v, n) => {
                self.u64(3);
                self.u64(v.len() as u64);
                for &x in v {
                    self.bytes(&x.to_bits().to_le_bytes());
                }
                self.names(n);
            }
            WireVal::Chr(v, n) => {
                self.u64(4);
                self.u64(v.len() as u64);
                for s in v {
                    self.str(s);
                }
                self.names(n);
            }
            WireVal::List(v, n, class) => {
                self.u64(5);
                self.u64(v.len() as u64);
                for x in v {
                    self.val(x);
                }
                self.names(n);
                match class {
                    None => self.u64(0),
                    Some(c) => {
                        self.u64(1);
                        self.str(c);
                    }
                }
            }
            WireVal::Builtin(k) => {
                self.u64(6);
                self.str(k);
            }
            // Closures and conditions are small and structural; hashing
            // their exact binary encoding is simpler than walking the
            // AST and just as deterministic (same-binary protocol).
            other @ (WireVal::Closure { .. } | WireVal::Cond(_)) => {
                self.u64(7);
                let enc = crate::wire::bin::to_bytes(other).unwrap_or_default();
                self.u64(enc.len() as u64);
                self.bytes(&enc);
            }
        }
    }
}

/// Content digest of one value — the address under which the data-plane
/// cache ships it (`CachePut`) and references it (`TaskContext::
/// cached_globals`). A structural walk over the in-memory value: no
/// encoding is forced and nothing is copied, so digesting an
/// `Arc`-frozen payload at freeze time is O(bytes hashed), zero
/// allocations.
pub fn digest_val(v: &WireVal) -> u64 {
    let mut h = Fnv::new();
    h.u64(0x11); // domain tag: single value
    h.val(v);
    h.0
}

/// Content digest of a frozen map-element vector
/// (`ElementSource::Items`). Domain-separated from [`digest_val`] so a
/// one-element vector never collides with its element.
pub fn digest_items(items: &[WireVal]) -> u64 {
    let mut h = Fnv::new();
    h.u64(0x22); // domain tag: items vector
    h.u64(items.len() as u64);
    for v in items {
        h.val(v);
    }
    h.0
}

/// Content digest of a frozen foreach binding vector
/// (`ElementSource::Bindings`).
pub fn digest_bindings(bindings: &[Vec<(String, WireVal)>]) -> u64 {
    let mut h = Fnv::new();
    h.u64(0x33); // domain tag: bindings vector
    h.u64(bindings.len() as u64);
    for row in bindings {
        h.u64(row.len() as u64);
        for (name, v) in row {
            h.str(name);
            h.val(v);
        }
    }
    h.0
}

/// A possibly-shared view of the per-chunk element payload inside
/// [`TaskKind`](crate::future_core::TaskKind) slice tasks — the
/// zero-copy fast path for in-process backends.
///
/// The dispatch core freezes a map call's elements once
/// (`Arc<Vec<T>>`) and hands every chunk a `Shared` window into that
/// storage: an `Arc` bump plus two indices, no per-chunk cloning or
/// encoding. This preserves the future framework's by-value snapshot
/// semantics because the shared storage is already an immutable
/// [`WireVal`] snapshot of the caller's values.
///
/// On the wire the two forms are indistinguishable: `Shared` serializes
/// as the plain element sequence its window covers, and deserializing
/// always produces `Owned` (the receiving process has no one to share
/// with).
#[derive(Clone, Debug)]
pub enum WireSlice<T> {
    Owned(Vec<T>),
    Shared { source: Arc<Vec<T>>, start: usize, end: usize },
}

impl<T> WireSlice<T> {
    /// A zero-copy window `source[start..end]`.
    pub fn shared(source: Arc<Vec<T>>, start: usize, end: usize) -> Self {
        debug_assert!(start <= end && end <= source.len());
        WireSlice::Shared { source, start, end }
    }

    pub fn as_slice(&self) -> &[T] {
        match self {
            WireSlice::Owned(v) => v,
            WireSlice::Shared { source, start, end } => &source[*start..*end],
        }
    }

    pub fn len(&self) -> usize {
        self.as_slice().len()
    }

    pub fn is_empty(&self) -> bool {
        self.as_slice().is_empty()
    }

    pub fn iter(&self) -> std::slice::Iter<'_, T> {
        self.as_slice().iter()
    }
}

impl<T> From<Vec<T>> for WireSlice<T> {
    fn from(v: Vec<T>) -> Self {
        WireSlice::Owned(v)
    }
}

impl<T: PartialEq> PartialEq for WireSlice<T> {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<'a, T> IntoIterator for &'a WireSlice<T> {
    type Item = &'a T;
    type IntoIter = std::slice::Iter<'a, T>;
    fn into_iter(self) -> Self::IntoIter {
        self.iter()
    }
}

impl<T: serde::Serialize> serde::Serialize for WireSlice<T> {
    fn serialize<S: serde::Serializer>(&self, s: S) -> Result<S::Ok, S::Error> {
        self.as_slice().serialize(s)
    }
}

impl<'de, T: serde::Deserialize<'de>> serde::Deserialize<'de> for WireSlice<T> {
    fn deserialize<D: serde::Deserializer<'de>>(d: D) -> Result<Self, D::Error> {
        Ok(WireSlice::Owned(Vec::<T>::deserialize(d)?))
    }
}

/// Convert a value to wire form, borrowing it (payload buffers are deep
/// copied — use [`to_wire_owned`] when the value can be consumed).
/// Closures capture their free variables by value; environments and
/// other live handles are rejected (they cannot meaningfully cross a
/// process boundary — same restriction as R).
pub fn to_wire(v: &RVal) -> Result<WireVal, String> {
    match v {
        RVal::Null => Ok(WireVal::Null),
        RVal::Lgl(x) => Ok(WireVal::Lgl(x.vals.to_vec(), x.names.clone())),
        RVal::Int(x) => Ok(WireVal::Int(x.vals.to_vec(), x.names.clone())),
        RVal::Dbl(x) => Ok(WireVal::Dbl(x.vals.to_vec(), x.names.clone())),
        RVal::Chr(x) => Ok(WireVal::Chr(x.vals.to_vec(), x.names.clone())),
        RVal::List(l) => {
            let vals: Result<Vec<WireVal>, String> = l.vals.iter().map(to_wire).collect();
            Ok(WireVal::List(vals?, l.names.clone(), l.class.clone()))
        }
        RVal::Builtin(id) => Ok(WireVal::Builtin(builtin_key(*id))),
        RVal::Cond(c) => Ok(WireVal::Cond((**c).clone())),
        RVal::Closure(c) => closure_to_wire(c),
        RVal::Env(_) => Err("cannot serialize an environment across processes".into()),
    }
}

/// Convert a value to wire form, consuming it: uniquely-owned COW
/// payload buffers *move* into the wire value instead of being deep
/// copied. A worker encoding its per-element results (which are almost
/// always freshly allocated, hence unique) pays zero buffer copies.
pub fn to_wire_owned(v: RVal) -> Result<WireVal, String> {
    match v {
        RVal::Null => Ok(WireVal::Null),
        RVal::Lgl(x) => {
            let (vals, names) = x.into_parts();
            Ok(WireVal::Lgl(vals, names))
        }
        RVal::Int(x) => {
            let (vals, names) = x.into_parts();
            Ok(WireVal::Int(vals, names))
        }
        RVal::Dbl(x) => {
            let (vals, names) = x.into_parts();
            Ok(WireVal::Dbl(vals, names))
        }
        RVal::Chr(x) => {
            let (vals, names) = x.into_parts();
            Ok(WireVal::Chr(vals, names))
        }
        RVal::List(l) => {
            let vals: Result<Vec<WireVal>, String> =
                l.vals.into_iter().map(to_wire_owned).collect();
            Ok(WireVal::List(vals?, l.names, l.class))
        }
        RVal::Builtin(id) => Ok(WireVal::Builtin(builtin_key(id))),
        RVal::Cond(c) => Ok(WireVal::Cond(*c)),
        RVal::Closure(c) => closure_to_wire(&c),
        RVal::Env(_) => Err("cannot serialize an environment across processes".into()),
    }
}

fn builtin_key(id: crate::rlite::builtins::BuiltinId) -> String {
    crate::rlite::builtins::builtin_by_id(id)
        .map(|d| d.key())
        .unwrap_or_else(|| format!("#invalid::{id}"))
}

fn closure_to_wire(c: &RClosure) -> Result<WireVal, String> {
    let mut captured = Vec::new();
    // Snapshot free variables of the body (minus the params).
    let body_fn = Expr::Function { params: c.params.clone(), body: Box::new(c.body.clone()) };
    for sym in globals::free_variables(&body_fn) {
        if let Some(val) = env::lookup_sym(&c.env, sym) {
            if matches!(val, RVal::Builtin(_)) {
                continue;
            }
            captured.push((sym.to_string(), to_wire_owned(val)?));
        }
        // Builtins and not-found symbols resolve on the worker.
    }
    Ok(WireVal::Closure { params: c.params.clone(), body: c.body.clone(), captured })
}

/// Reconstruct a value on the worker side, borrowing the wire value
/// (payload buffers are copied — use [`from_wire_owned`] when the wire
/// value can be consumed). Closures are re-rooted on a fresh environment
/// seeded with their captured variables, whose parent is `base_env` (the
/// worker's global environment).
pub fn from_wire(w: &WireVal, base_env: &EnvRef) -> RVal {
    match w {
        WireVal::Null => RVal::Null,
        WireVal::Lgl(v, n) => RVal::Lgl(RVec::with_names(v.clone(), n.clone())),
        WireVal::Int(v, n) => RVal::Int(RVec::with_names(v.clone(), n.clone())),
        WireVal::Dbl(v, n) => RVal::Dbl(RVec::with_names(v.clone(), n.clone())),
        WireVal::Chr(v, n) => RVal::Chr(RVec::with_names(v.clone(), n.clone())),
        WireVal::List(v, n, class) => RVal::List(RList {
            vals: v.iter().map(|x| from_wire(x, base_env)).collect(),
            names: n.clone(),
            class: class.clone(),
        }),
        WireVal::Builtin(key) => builtin_from_key(key, base_env),
        WireVal::Cond(c) => RVal::Cond(Box::new(c.clone())),
        WireVal::Closure { params, body, captured } => {
            let env = Env::child_of(base_env);
            for (name, val) in captured {
                env::define(&env, name, from_wire(val, base_env));
            }
            RVal::Closure(std::rc::Rc::new(RClosure {
                params: params.clone(),
                body: body.clone(),
                env,
            }))
        }
    }
}

/// Reconstruct a value on the worker side, consuming the wire value:
/// decoded payload buffers *move* into the COW representation instead of
/// being copied again — the worker-side half of the decode fast path.
pub fn from_wire_owned(w: WireVal, base_env: &EnvRef) -> RVal {
    match w {
        WireVal::Null => RVal::Null,
        WireVal::Lgl(v, n) => RVal::Lgl(RVec::with_names(v, n)),
        WireVal::Int(v, n) => RVal::Int(RVec::with_names(v, n)),
        WireVal::Dbl(v, n) => RVal::Dbl(RVec::with_names(v, n)),
        WireVal::Chr(v, n) => RVal::Chr(RVec::with_names(v, n)),
        WireVal::List(v, n, class) => RVal::List(RList {
            vals: v.into_iter().map(|x| from_wire_owned(x, base_env)).collect(),
            names: n,
            class,
        }),
        WireVal::Builtin(key) => builtin_from_key(&key, base_env),
        WireVal::Cond(c) => RVal::Cond(Box::new(c)),
        WireVal::Closure { params, body, captured } => {
            let env = Env::child_of(base_env);
            for (name, val) in captured {
                env::define(&env, &name, from_wire_owned(val, base_env));
            }
            RVal::Closure(std::rc::Rc::new(RClosure { params, body, env }))
        }
    }
}

fn builtin_from_key(key: &str, base_env: &EnvRef) -> RVal {
    if let Some(id) = crate::rlite::builtins::id_for_key(key)
        // Tolerate unqualified legacy keys ("sum" for "base::sum").
        .or_else(|| crate::rlite::builtins::lookup_builtin(key).map(|d| d.id))
    {
        return RVal::Builtin(id);
    }
    // Same-binary protocol: a genuinely unknown key cannot normally
    // occur (registry skew, renamed builtin). Preserve the old deferred
    // semantics: the value stays a function (`is.function` is TRUE) and
    // raises a named error when actually called.
    let msg = format!("unknown builtin '{key}' in this worker's registry");
    RVal::Closure(std::rc::Rc::new(RClosure {
        params: vec![crate::rlite::ast::Param { name: "...".into(), default: None }],
        body: Expr::call("stop", vec![crate::rlite::ast::Arg::pos(Expr::Str(msg))]),
        env: base_env.clone(),
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rlite::eval::Interp;
    use crate::rlite::env::define;

    #[test]
    fn atomic_roundtrip() {
        let v = RVal::dbl(vec![1.0, 2.0]);
        let w = to_wire(&v).unwrap();
        let base = Env::new_ref();
        assert_eq!(from_wire(&w, &base), v);
    }

    #[test]
    fn closure_captures_free_vars_by_value() {
        let mut i = Interp::new();
        i.eval_program("a <- 10\nf <- function(x) x + a").unwrap();
        let f = env::lookup(&i.global, "f").unwrap();
        let w = to_wire(&f).unwrap();
        // Mutate `a` after capture: the wire copy must keep the old value.
        i.eval_program("a <- 999").unwrap();
        let mut worker = Interp::new();
        let g = from_wire(&w, &worker.global);
        let genv = worker.global.clone();
        define(&genv, "g", g);
        let r = worker.eval_program("g(5)").unwrap();
        assert_eq!(r, RVal::scalar_dbl(15.0));
    }

    #[test]
    fn nested_closure_capture() {
        let mut i = Interp::new();
        i.eval_program("b <- 2\ninner <- function(y) y * b\nf <- function(x) inner(x) + 1")
            .unwrap();
        let f = env::lookup(&i.global, "f").unwrap();
        let w = to_wire(&f).unwrap();
        let mut worker = Interp::new();
        let g = from_wire(&w, &worker.global);
        define(&worker.global.clone(), "g", g);
        assert_eq!(worker.eval_program("g(4)").unwrap(), RVal::scalar_dbl(9.0));
    }

    #[test]
    fn env_is_rejected() {
        let env = Env::new_ref();
        assert!(to_wire(&RVal::Env(env)).is_err());
    }

    #[test]
    fn known_builtin_key_decodes_to_builtin() {
        let base = Env::new_ref();
        let v = from_wire(&WireVal::Builtin("base::sum".into()), &base);
        assert!(matches!(v, RVal::Builtin(_)));
        // Legacy unqualified keys resolve through the search path.
        let v = from_wire(&WireVal::Builtin("sum".into()), &base);
        assert!(matches!(v, RVal::Builtin(_)));
    }

    #[test]
    fn unknown_builtin_key_decodes_to_error_raising_function() {
        // Registry skew must surface as a *named* error at call time,
        // not silently decode to NULL.
        let mut i = Interp::new();
        let base = i.global.clone();
        let v = from_wire(&WireVal::Builtin("nosuchpkg::nosuchfn".into()), &base);
        assert!(v.is_function(), "decoded value must still be a function");
        let r = i.call_function(&v, vec![], &base);
        match r {
            Err(crate::rlite::eval::Signal::Error(c)) => {
                assert!(c.message.contains("nosuchpkg::nosuchfn"), "{}", c.message)
            }
            other => panic!("expected a named error, got {other:?}"),
        }
    }

    #[test]
    fn wire_slice_shared_serializes_like_owned() {
        let source = Arc::new(vec![
            WireVal::Dbl(vec![1.0], None),
            WireVal::Dbl(vec![2.0], None),
            WireVal::Dbl(vec![3.0], None),
        ]);
        let shared = WireSlice::shared(source.clone(), 1, 3);
        let owned: WireSlice<WireVal> = WireSlice::Owned(source[1..3].to_vec());
        assert_eq!(shared, owned);
        assert_eq!(
            crate::wire::bin::to_bytes(&shared).unwrap(),
            crate::wire::bin::to_bytes(&owned).unwrap(),
            "shared and owned windows must be wire-identical"
        );
        let bytes = crate::wire::bin::to_bytes(&shared).unwrap();
        let back: WireSlice<WireVal> = crate::wire::bin::from_bytes(&bytes).unwrap();
        assert_eq!(back, shared);
        assert!(matches!(back, WireSlice::Owned(_)), "decode always owns");
    }

    #[test]
    fn json_roundtrip_of_wire() {
        let w = WireVal::List(
            vec![WireVal::Dbl(vec![1.0], None), WireVal::Chr(vec!["a".into()], None)],
            Some(vec!["x".into(), "y".into()]),
            None,
        );
        let s = crate::wire::to_string(&w).unwrap();
        let back: WireVal = crate::wire::from_str(&s).unwrap();
        assert_eq!(w, back);
    }
}
