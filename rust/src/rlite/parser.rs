//! Recursive-descent parser for rlite.
//!
//! Operator precedence follows R (low → high):
//!
//! `<- = ->`  <  `| ||`  <  `& &&`  <  `!`  <  comparisons  <  `+ -`
//! <  `* /`  <  `%op%` and `|>`  <  `:`  <  unary `-`  <  `^`
//! <  postfix (`f()`, `x[..]`, `x[[..]]`, `$`, `::`).
//!
//! The native pipe is desugared at parse time exactly as in R 4.1:
//! `lhs |> f(a, b)` becomes `f(lhs, a, b)`; `lhs |> f` becomes `f(lhs)`.
//! Newlines terminate statements at top level but are transparent inside
//! any bracketed context and after a binary operator.

use super::ast::{Arg, Expr, Param};
use super::lexer::{Tok, Token};

pub struct Parser {
    toks: Vec<Token>,
    pos: usize,
    /// Nesting depth of `(`, `[`, `[[` — newlines are transparent when > 0.
    depth: usize,
}

impl Parser {
    pub fn new(toks: Vec<Token>) -> Self {
        Parser { toks, pos: 0, depth: 0 }
    }

    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.pos).map(|t| &t.kind)
    }

    fn peek_at(&self, n: usize) -> Option<&Tok> {
        self.toks.get(self.pos + n).map(|t| &t.kind)
    }

    fn bump(&mut self) -> Option<Tok> {
        let t = self.toks.get(self.pos).map(|t| t.kind.clone());
        if t.is_some() {
            self.pos += 1;
        }
        t
    }

    fn err(&self, msg: &str) -> String {
        match self.toks.get(self.pos) {
            Some(t) => format!("parse error at {}:{}: {} (found {:?})", t.line, t.col, msg, t.kind),
            None => format!("parse error at end of input: {msg}"),
        }
    }

    fn eat(&mut self, want: &Tok, what: &str) -> Result<(), String> {
        if self.peek() == Some(want) {
            self.bump();
            Ok(())
        } else {
            Err(self.err(&format!("expected {what}")))
        }
    }

    fn skip_newlines(&mut self) {
        while matches!(self.peek(), Some(Tok::Newline)) {
            self.bump();
        }
    }

    fn skip_separators(&mut self) {
        while matches!(self.peek(), Some(Tok::Newline) | Some(Tok::Semi)) {
            self.bump();
        }
    }

    /// Peek the next token, looking through newlines when inside brackets.
    fn peek_op(&mut self) -> Option<&Tok> {
        if self.depth > 0 {
            self.skip_newlines();
        }
        self.peek()
    }

    pub fn parse_program(&mut self) -> Result<Vec<Expr>, String> {
        let mut out = Vec::new();
        loop {
            self.skip_separators();
            if self.peek().is_none() {
                break;
            }
            out.push(self.parse_expr()?);
            // An expression must be followed by a separator or EOF.
            match self.peek() {
                None | Some(Tok::Newline) | Some(Tok::Semi) => {}
                Some(_) => return Err(self.err("expected end of statement")),
            }
        }
        Ok(out)
    }

    pub fn parse_expr(&mut self) -> Result<Expr, String> {
        self.parse_assign()
    }

    fn parse_assign(&mut self) -> Result<Expr, String> {
        let lhs = self.parse_formula()?;
        match self.peek_op() {
            Some(Tok::LeftAssign) | Some(Tok::Eq) => {
                self.bump();
                self.skip_newlines();
                let rhs = self.parse_assign()?;
                Ok(Expr::Assign { target: Box::new(lhs), value: Box::new(rhs) })
            }
            Some(Tok::SuperAssign) => {
                self.bump();
                self.skip_newlines();
                let rhs = self.parse_assign()?;
                Ok(Expr::SuperAssign { target: Box::new(lhs), value: Box::new(rhs) })
            }
            Some(Tok::RightAssign) => {
                self.bump();
                self.skip_newlines();
                let target = self.parse_formula()?;
                Ok(Expr::Assign { target: Box::new(target), value: Box::new(lhs) })
            }
            _ => Ok(lhs),
        }
    }

    /// `lhs ~ rhs` and unary `~ rhs` (model formulas). Lower precedence
    /// than `|`/`||` so `y ~ x + (1 | g)` groups as expected.
    fn parse_formula(&mut self) -> Result<Expr, String> {
        if matches!(self.peek(), Some(Tok::Tilde)) {
            self.bump();
            self.skip_newlines();
            let rhs = self.parse_or()?;
            return Ok(Expr::call("~", vec![Arg::pos(rhs)]));
        }
        let lhs = self.parse_or()?;
        if matches!(self.peek_op(), Some(Tok::Tilde)) {
            self.bump();
            self.skip_newlines();
            let rhs = self.parse_or()?;
            return Ok(Expr::call("~", vec![Arg::pos(lhs), Arg::pos(rhs)]));
        }
        Ok(lhs)
    }

    fn parse_or(&mut self) -> Result<Expr, String> {
        let mut lhs = self.parse_and()?;
        loop {
            let op = match self.peek_op() {
                Some(Tok::Or) => "|",
                Some(Tok::OrOr) => "||",
                _ => break,
            };
            self.bump();
            self.skip_newlines();
            let rhs = self.parse_and()?;
            lhs = Expr::call(op, vec![Arg::pos(lhs), Arg::pos(rhs)]);
        }
        Ok(lhs)
    }

    fn parse_and(&mut self) -> Result<Expr, String> {
        let mut lhs = self.parse_not()?;
        loop {
            let op = match self.peek_op() {
                Some(Tok::And) => "&",
                Some(Tok::AndAnd) => "&&",
                _ => break,
            };
            self.bump();
            self.skip_newlines();
            let rhs = self.parse_not()?;
            lhs = Expr::call(op, vec![Arg::pos(lhs), Arg::pos(rhs)]);
        }
        Ok(lhs)
    }

    fn parse_not(&mut self) -> Result<Expr, String> {
        if matches!(self.peek(), Some(Tok::Bang)) {
            self.bump();
            self.skip_newlines();
            let e = self.parse_not()?;
            Ok(Expr::call("!", vec![Arg::pos(e)]))
        } else {
            self.parse_cmp()
        }
    }

    fn parse_cmp(&mut self) -> Result<Expr, String> {
        let mut lhs = self.parse_add()?;
        loop {
            let op = match self.peek_op() {
                Some(Tok::EqEq) => "==",
                Some(Tok::Neq) => "!=",
                Some(Tok::Lt) => "<",
                Some(Tok::Gt) => ">",
                Some(Tok::Le) => "<=",
                Some(Tok::Ge) => ">=",
                _ => break,
            };
            self.bump();
            self.skip_newlines();
            let rhs = self.parse_add()?;
            lhs = Expr::call(op, vec![Arg::pos(lhs), Arg::pos(rhs)]);
        }
        Ok(lhs)
    }

    fn parse_add(&mut self) -> Result<Expr, String> {
        let mut lhs = self.parse_mul()?;
        loop {
            let op = match self.peek_op() {
                Some(Tok::Plus) => "+",
                Some(Tok::Minus) => "-",
                _ => break,
            };
            self.bump();
            self.skip_newlines();
            let rhs = self.parse_mul()?;
            lhs = Expr::call(op, vec![Arg::pos(lhs), Arg::pos(rhs)]);
        }
        Ok(lhs)
    }

    fn parse_mul(&mut self) -> Result<Expr, String> {
        let mut lhs = self.parse_special()?;
        loop {
            let op = match self.peek_op() {
                Some(Tok::Star) => "*",
                Some(Tok::Slash) => "/",
                _ => break,
            };
            self.bump();
            self.skip_newlines();
            let rhs = self.parse_special()?;
            lhs = Expr::call(op, vec![Arg::pos(lhs), Arg::pos(rhs)]);
        }
        Ok(lhs)
    }

    /// `%op%` user infixes and the native pipe `|>` share a precedence
    /// level (left-associative), as in R.
    fn parse_special(&mut self) -> Result<Expr, String> {
        let mut lhs = self.parse_range()?;
        loop {
            match self.peek_op().cloned() {
                Some(Tok::Infix(name)) => {
                    self.bump();
                    self.skip_newlines();
                    let rhs = self.parse_range()?;
                    lhs = Expr::call(&name, vec![Arg::pos(lhs), Arg::pos(rhs)]);
                }
                Some(Tok::Pipe) => {
                    self.bump();
                    self.skip_newlines();
                    let rhs = self.parse_range()?;
                    lhs = desugar_pipe(lhs, rhs)?;
                }
                _ => break,
            }
        }
        Ok(lhs)
    }

    fn parse_range(&mut self) -> Result<Expr, String> {
        let mut lhs = self.parse_unary()?;
        while matches!(self.peek_op(), Some(Tok::Colon)) {
            self.bump();
            self.skip_newlines();
            let rhs = self.parse_unary()?;
            lhs = Expr::call(":", vec![Arg::pos(lhs), Arg::pos(rhs)]);
        }
        Ok(lhs)
    }

    fn parse_unary(&mut self) -> Result<Expr, String> {
        match self.peek() {
            Some(Tok::Minus) => {
                self.bump();
                let e = self.parse_unary()?;
                // Constant-fold negative literals for readable deparse.
                Ok(match e {
                    Expr::Num(v) => Expr::Num(-v),
                    Expr::Int(v) => Expr::Int(-v),
                    other => Expr::call("-", vec![Arg::pos(other)]),
                })
            }
            Some(Tok::Plus) => {
                self.bump();
                self.parse_unary()
            }
            _ => self.parse_power(),
        }
    }

    fn parse_power(&mut self) -> Result<Expr, String> {
        let base = self.parse_postfix()?;
        if matches!(self.peek_op(), Some(Tok::Caret)) {
            self.bump();
            self.skip_newlines();
            let exp = self.parse_unary()?; // right-assoc
            Ok(Expr::call("^", vec![Arg::pos(base), Arg::pos(exp)]))
        } else {
            Ok(base)
        }
    }

    fn parse_postfix(&mut self) -> Result<Expr, String> {
        let mut e = self.parse_primary()?;
        loop {
            match self.peek() {
                Some(Tok::LParen) => {
                    self.bump();
                    self.depth += 1;
                    let args = self.parse_args(&Tok::RParen)?;
                    self.depth -= 1;
                    self.eat(&Tok::RParen, ")")?;
                    e = Expr::Call { func: Box::new(e), args };
                }
                Some(Tok::LBracket) => {
                    self.bump();
                    self.depth += 1;
                    let args = self.parse_args(&Tok::RBracket)?;
                    self.depth -= 1;
                    self.eat(&Tok::RBracket, "]")?;
                    e = Expr::Index { obj: Box::new(e), args, double: false };
                }
                Some(Tok::DoubleLBracket) => {
                    self.bump();
                    self.depth += 1;
                    let args = self.parse_args(&Tok::DoubleRBracket)?;
                    self.depth -= 1;
                    self.eat(&Tok::DoubleRBracket, "]]")?;
                    e = Expr::Index { obj: Box::new(e), args, double: true };
                }
                Some(Tok::Dollar) => {
                    self.bump();
                    match self.bump() {
                        Some(Tok::Ident(name)) => {
                            e = Expr::Dollar { obj: Box::new(e), name };
                        }
                        Some(Tok::Str(name)) => {
                            e = Expr::Dollar { obj: Box::new(e), name };
                        }
                        _ => return Err(self.err("expected name after $")),
                    }
                }
                _ => break,
            }
        }
        Ok(e)
    }

    /// Parse a comma-separated argument list up to (not including) `end`.
    /// Handles named arguments (`n = 10`), elided arguments, and `...`.
    fn parse_args(&mut self, end: &Tok) -> Result<Vec<Arg>, String> {
        let mut args = Vec::new();
        self.skip_newlines();
        if self.peek() == Some(end) {
            return Ok(args);
        }
        loop {
            self.skip_newlines();
            // Elided argument: `x[, 1]` or trailing `f(a, )`.
            if self.peek() == Some(&Tok::Comma) || self.peek() == Some(end) {
                args.push(Arg::pos(Expr::Missing));
            } else {
                // Named argument lookahead: Ident/Str `=` (but not `==`).
                let named = match (self.peek(), self.peek_at(1)) {
                    (Some(Tok::Ident(_)), Some(Tok::Eq)) | (Some(Tok::Str(_)), Some(Tok::Eq)) => {
                        true
                    }
                    _ => false,
                };
                if named {
                    let name = match self.bump() {
                        Some(Tok::Ident(n)) | Some(Tok::Str(n)) => n,
                        _ => unreachable!(),
                    };
                    self.bump(); // =
                    self.skip_newlines();
                    let value = self.parse_or_missing(end)?;
                    args.push(Arg { name: Some(name), value });
                } else {
                    let value = self.parse_expr()?;
                    args.push(Arg::pos(value));
                }
            }
            self.skip_newlines();
            if self.peek() == Some(&Tok::Comma) {
                self.bump();
                self.skip_newlines();
                if self.peek() == Some(end) {
                    break; // trailing comma
                }
            } else {
                break;
            }
        }
        Ok(args)
    }

    fn parse_or_missing(&mut self, end: &Tok) -> Result<Expr, String> {
        if self.peek() == Some(&Tok::Comma) || self.peek() == Some(end) {
            Ok(Expr::Missing)
        } else {
            self.parse_expr()
        }
    }

    fn parse_params(&mut self) -> Result<Vec<Param>, String> {
        self.eat(&Tok::LParen, "( after function")?;
        self.depth += 1;
        let mut params = Vec::new();
        self.skip_newlines();
        while self.peek() != Some(&Tok::RParen) {
            let name = match self.bump() {
                Some(Tok::Ident(n)) => n,
                Some(Tok::Dots) => "...".to_string(),
                _ => {
                    self.depth -= 1;
                    return Err(self.err("expected parameter name"));
                }
            };
            let default = if self.peek() == Some(&Tok::Eq) {
                self.bump();
                self.skip_newlines();
                Some(self.parse_expr()?)
            } else {
                None
            };
            params.push(Param { name: name.into(), default });
            self.skip_newlines();
            if self.peek() == Some(&Tok::Comma) {
                self.bump();
                self.skip_newlines();
            } else {
                break;
            }
        }
        self.depth -= 1;
        self.eat(&Tok::RParen, ") after parameters")?;
        Ok(params)
    }

    fn parse_primary(&mut self) -> Result<Expr, String> {
        match self.peek().cloned() {
            Some(Tok::Num(v)) => {
                self.bump();
                Ok(Expr::Num(v))
            }
            Some(Tok::Int(v)) => {
                self.bump();
                Ok(Expr::Int(v))
            }
            Some(Tok::Str(s)) => {
                self.bump();
                Ok(Expr::Str(s))
            }
            Some(Tok::True) => {
                self.bump();
                Ok(Expr::Bool(true))
            }
            Some(Tok::False) => {
                self.bump();
                Ok(Expr::Bool(false))
            }
            Some(Tok::Null) => {
                self.bump();
                Ok(Expr::Null)
            }
            Some(Tok::Na) => {
                self.bump();
                Ok(Expr::Num(f64::NAN)) // simplified NA model
            }
            Some(Tok::Inf) => {
                self.bump();
                Ok(Expr::Num(f64::INFINITY))
            }
            Some(Tok::NaN) => {
                self.bump();
                Ok(Expr::Num(f64::NAN))
            }
            Some(Tok::Dots) => {
                self.bump();
                Ok(Expr::Dots)
            }
            Some(Tok::Break) => {
                self.bump();
                Ok(Expr::Break)
            }
            Some(Tok::Next) => {
                self.bump();
                Ok(Expr::Next)
            }
            Some(Tok::Ident(name)) => {
                self.bump();
                if self.peek() == Some(&Tok::DoubleColon) {
                    self.bump();
                    match self.bump() {
                        Some(Tok::Ident(fname)) => Ok(Expr::Ns { pkg: name, name: fname }),
                        _ => Err(self.err("expected name after ::")),
                    }
                } else if name == "..." {
                    Ok(Expr::Dots)
                } else {
                    Ok(Expr::Sym(name.into()))
                }
            }
            Some(Tok::LParen) => {
                self.bump();
                self.depth += 1;
                self.skip_newlines();
                let e = self.parse_expr()?;
                self.skip_newlines();
                self.depth -= 1;
                self.eat(&Tok::RParen, ")")?;
                // `( e )` is semantically transparent but kept as a call so
                // the transpiler can unwrap it, mirroring R's `(`.
                Ok(Expr::call("(", vec![Arg::pos(e)]))
            }
            Some(Tok::LBrace) => {
                self.bump();
                // Inside a block, newlines separate statements again even
                // if the block itself sits inside parentheses.
                let saved_depth = std::mem::take(&mut self.depth);
                let mut body = Vec::new();
                loop {
                    self.skip_separators();
                    if self.peek() == Some(&Tok::RBrace) {
                        break;
                    }
                    if self.peek().is_none() {
                        return Err(self.err("unterminated { block"));
                    }
                    body.push(self.parse_expr()?);
                    match self.peek() {
                        Some(Tok::Newline) | Some(Tok::Semi) | Some(Tok::RBrace) => {}
                        _ => return Err(self.err("expected end of statement in block")),
                    }
                }
                self.eat(&Tok::RBrace, "}")?;
                self.depth = saved_depth;
                Ok(Expr::Block(body))
            }
            Some(Tok::Function) => {
                self.bump();
                let params = self.parse_params()?;
                self.skip_newlines();
                let body = self.parse_expr()?;
                Ok(Expr::Function { params, body: Box::new(body) })
            }
            Some(Tok::Backslash) => {
                self.bump();
                let params = self.parse_params()?;
                self.skip_newlines();
                let body = self.parse_expr()?;
                Ok(Expr::Function { params, body: Box::new(body) })
            }
            Some(Tok::If) => {
                self.bump();
                self.eat(&Tok::LParen, "( after if")?;
                self.depth += 1;
                self.skip_newlines();
                let cond = self.parse_expr()?;
                self.skip_newlines();
                self.depth -= 1;
                self.eat(&Tok::RParen, ") after if condition")?;
                self.skip_newlines();
                let then = self.parse_expr()?;
                // Allow `else` after newline (R allows this inside blocks;
                // we allow it everywhere for simplicity).
                let save = self.pos;
                self.skip_newlines();
                let els = if self.peek() == Some(&Tok::Else) {
                    self.bump();
                    self.skip_newlines();
                    Some(Box::new(self.parse_expr()?))
                } else {
                    self.pos = save;
                    None
                };
                Ok(Expr::If { cond: Box::new(cond), then: Box::new(then), els })
            }
            Some(Tok::For) => {
                self.bump();
                self.eat(&Tok::LParen, "( after for")?;
                let var = match self.bump() {
                    Some(Tok::Ident(n)) => n,
                    _ => return Err(self.err("expected loop variable")),
                };
                self.eat(&Tok::In, "in")?;
                self.depth += 1;
                let seq = self.parse_expr()?;
                self.depth -= 1;
                self.eat(&Tok::RParen, ") after for")?;
                self.skip_newlines();
                let body = self.parse_expr()?;
                Ok(Expr::For { var: var.into(), seq: Box::new(seq), body: Box::new(body) })
            }
            Some(Tok::While) => {
                self.bump();
                self.eat(&Tok::LParen, "( after while")?;
                self.depth += 1;
                let cond = self.parse_expr()?;
                self.depth -= 1;
                self.eat(&Tok::RParen, ") after while")?;
                self.skip_newlines();
                let body = self.parse_expr()?;
                Ok(Expr::While { cond: Box::new(cond), body: Box::new(body) })
            }
            _ => Err(self.err("expected expression")),
        }
    }
}

/// R 4.1 native-pipe desugaring: `lhs |> f(a)` → `f(lhs, a)`;
/// `lhs |> pkg::f()` → `pkg::f(lhs)`; a bare function name is also
/// accepted (`lhs |> f` → `f(lhs)`).
fn desugar_pipe(lhs: Expr, rhs: Expr) -> Result<Expr, String> {
    match rhs {
        Expr::Call { func, mut args } => {
            args.insert(0, Arg::pos(lhs));
            Ok(Expr::Call { func, args })
        }
        f @ (Expr::Sym(_) | Expr::Ns { .. }) => {
            Ok(Expr::Call { func: Box::new(f), args: vec![Arg::pos(lhs)] })
        }
        other => Err(format!("invalid rhs of |>: {:?}", other)),
    }
}

#[cfg(test)]
mod tests {
    use super::super::{parse_expr, parse_program};
    use super::*;

    #[test]
    fn parses_pipe_to_futurize() {
        let e = parse_expr("lapply(xs, fcn) |> futurize()").unwrap();
        assert_eq!(e.call_name(), Some("futurize"));
        let (_, args) = e.as_call().unwrap();
        assert_eq!(args.len(), 1);
        assert_eq!(args[0].value.call_name(), Some("lapply"));
    }

    #[test]
    fn pipe_bare_function() {
        let e = parse_expr("x |> sqrt").unwrap();
        assert_eq!(e.call_name(), Some("sqrt"));
    }

    #[test]
    fn pipe_inserts_first() {
        let e = parse_expr("xs |> map(f, n = 10)").unwrap();
        let (_, args) = e.as_call().unwrap();
        assert_eq!(args.len(), 3);
        assert_eq!(args[0].value, Expr::Sym("xs".into()));
        assert_eq!(args[2].name.as_deref(), Some("n"));
    }

    #[test]
    fn do_infix_binds_before_pipe_left_assoc() {
        // ((foreach(x = xs) %do% { ... }) |> futurize())
        let e = parse_expr("foreach(x = xs) %do% { slow_fcn(x) } |> futurize()").unwrap();
        assert_eq!(e.call_name(), Some("futurize"));
        let (_, args) = e.as_call().unwrap();
        assert_eq!(args[0].value.call_name(), Some("%do%"));
    }

    #[test]
    fn range_binds_tighter_than_pipe() {
        let e = parse_expr("1:100 |> map(f)").unwrap();
        let (_, args) = e.as_call().unwrap();
        assert_eq!(args[0].value.call_name(), Some(":"));
    }

    #[test]
    fn assignment_and_multiline_pipeline() {
        let prog = parse_program(
            "ys <- 1:100 |>\n  map(rnorm, n = 10) |> futurize(seed = TRUE) |>\n  map_dbl(mean) |> futurize()\n",
        )
        .unwrap();
        assert_eq!(prog.len(), 1);
        match &prog[0] {
            Expr::Assign { value, .. } => assert_eq!(value.call_name(), Some("futurize")),
            other => panic!("expected assign, got {other:?}"),
        }
    }

    #[test]
    fn function_definition_with_default() {
        let e = parse_expr("function(x, n = 10) { x + n }").unwrap();
        match e {
            Expr::Function { params, .. } => {
                assert_eq!(params.len(), 2);
                assert_eq!(params[1].default, Some(Expr::Num(10.0)));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn lambda_shorthand() {
        let e = parse_expr(r"\(x) sqrt(x)").unwrap();
        assert!(matches!(e, Expr::Function { .. }));
    }

    #[test]
    fn namespaced_call() {
        let e = parse_expr("purrr::map(xs, f)").unwrap();
        assert_eq!(e.call_name(), Some("map"));
        assert_eq!(e.call_namespace(), Some("purrr"));
    }

    #[test]
    fn precedence_power_and_unary() {
        // -x^2 parses as -(x^2)
        let e = parse_expr("-x^2").unwrap();
        assert_eq!(e.call_name(), Some("-"));
        let (_, args) = e.as_call().unwrap();
        assert_eq!(args[0].value.call_name(), Some("^"));
    }

    #[test]
    fn block_with_statements() {
        let e = parse_expr("{\n a <- 1\n b <- 2\n a + b\n}").unwrap();
        match e {
            Expr::Block(stmts) => assert_eq!(stmts.len(), 3),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn if_else_and_for() {
        let e = parse_expr("if (x > 1) 1 else 2").unwrap();
        assert!(matches!(e, Expr::If { els: Some(_), .. }));
        let e = parse_expr("for (i in 1:10) { s <- s + i }").unwrap();
        assert!(matches!(e, Expr::For { .. }));
    }

    #[test]
    fn double_bracket_index() {
        let e = parse_expr("xs[[3]]").unwrap();
        assert!(matches!(e, Expr::Index { double: true, .. }));
        let e = parse_expr("df$a").unwrap();
        assert!(matches!(e, Expr::Dollar { .. }));
    }

    #[test]
    fn right_assign() {
        let e = parse_expr("1 + 2 -> y").unwrap();
        match e {
            Expr::Assign { target, .. } => assert_eq!(*target, Expr::Sym("y".into())),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn elided_args() {
        let e = parse_expr("x[, 1]").unwrap();
        match e {
            Expr::Index { args, .. } => {
                assert_eq!(args.len(), 2);
                assert_eq!(args[0].value, Expr::Missing);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn program_with_library_calls() {
        let prog = parse_program(
            "library(future)\nplan(multisession)\nxs <- 1:100\nys <- lapply(xs, slow_fcn) |> futurize()\n",
        )
        .unwrap();
        assert_eq!(prog.len(), 4);
    }

    #[test]
    fn times_do_pipe_chain() {
        let e = parse_expr("times(100) %do% rnorm(10) |> futurize()").unwrap();
        assert_eq!(e.call_name(), Some("futurize"));
        let (_, args) = e.as_call().unwrap();
        assert_eq!(args[0].value.call_name(), Some("%do%"));
    }

    #[test]
    fn trailing_else_after_newline() {
        let e = parse_expr("{ if (x) 1\n else 2 }").unwrap();
        match e {
            Expr::Block(v) => assert!(matches!(v[0], Expr::If { els: Some(_), .. })),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn suppress_wrapper_chain_parses() {
        let e = parse_expr("{ lapply(xs, fcn) } |> suppressMessages() |> futurize()").unwrap();
        assert_eq!(e.call_name(), Some("futurize"));
    }
}
