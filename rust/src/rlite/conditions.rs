//! The rlite condition system.
//!
//! Section 4.9 of the paper ("Familiar behavior of stdout and condition
//! handling") is a headline feature of the future ecosystem: output and
//! conditions produced on parallel workers are captured there and
//! *relayed as-is* in the parent session, where they can be handled with
//! the ordinary sequential tools (`suppressMessages()`, `tryCatch()`,
//! ...). This module defines the condition objects, the capture record a
//! worker produces, and the severity taxonomy; the handler stack lives in
//! the interpreter ([`crate::rlite::eval`]).

use serde_derive::{Deserialize, Serialize};

/// Condition severity (drives default side effects and relay behaviour).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Serialize, Deserialize)]
pub enum Severity {
    /// `message()` — printed to stderr, continues.
    Message,
    /// `warning()` — collected, continues.
    Warning,
    /// `stop()` — aborts evaluation.
    Error,
    /// A custom signaled condition (e.g. progress updates) — inert unless
    /// a handler/collector is interested.
    Custom,
}

/// A condition object. `classes` mirrors R's condition class vector,
/// most-specific first (e.g. `["progress", "condition"]`).
#[derive(Clone, Debug, PartialEq, Serialize, Deserialize)]
pub struct RCondition {
    pub severity: Severity,
    pub message: String,
    pub classes: Vec<String>,
    /// Call text for error messages ("Error in f(x): ..."), if known.
    pub call: Option<String>,
    /// Structured payload for custom conditions (e.g. progress step).
    pub data: Option<crate::wire::JsonValue>,
}

impl RCondition {
    pub fn message_cond(msg: impl Into<String>) -> Self {
        RCondition {
            severity: Severity::Message,
            message: msg.into(),
            classes: vec!["simpleMessage".into(), "message".into(), "condition".into()],
            call: None,
            data: None,
        }
    }

    pub fn warning_cond(msg: impl Into<String>) -> Self {
        RCondition {
            severity: Severity::Warning,
            message: msg.into(),
            classes: vec!["simpleWarning".into(), "warning".into(), "condition".into()],
            call: None,
            data: None,
        }
    }

    pub fn error_cond(msg: impl Into<String>) -> Self {
        RCondition {
            severity: Severity::Error,
            message: msg.into(),
            classes: vec!["simpleError".into(), "error".into(), "condition".into()],
            call: None,
            data: None,
        }
    }

    pub fn custom(
        class: &str,
        msg: impl Into<String>,
        data: Option<crate::wire::JsonValue>,
    ) -> Self {
        RCondition {
            severity: Severity::Custom,
            message: msg.into(),
            classes: vec![class.to_string(), "condition".into()],
            call: None,
            data,
        }
    }

    pub fn with_call(mut self, call: impl Into<String>) -> Self {
        self.call = Some(call.into());
        self
    }

    /// Most specific class.
    pub fn primary_class(&self) -> &str {
        self.classes.first().map(String::as_str).unwrap_or("condition")
    }

    /// Does this condition inherit from `class`?
    pub fn inherits(&self, class: &str) -> bool {
        self.classes.iter().any(|c| c == class)
    }

    /// Render the default display (what an unhandled condition prints).
    pub fn render(&self) -> String {
        match self.severity {
            Severity::Message => self.message.clone(),
            Severity::Warning => format!("Warning message:\n{}", self.message),
            Severity::Error => match &self.call {
                Some(call) => format!("Error in {}: {}", call, self.message),
                None => format!("Error: {}", self.message),
            },
            Severity::Custom => self.message.clone(),
        }
    }
}

/// Everything a worker captured while evaluating a task, shipped back to
/// the parent verbatim so it can be relayed "as-is" (paper §4.9). This is
/// the future-ecosystem `FutureResult` analog.
#[derive(Clone, Debug, Default, PartialEq, Serialize, Deserialize)]
pub struct CaptureLog {
    /// Captured stdout, in order (from `cat()`, `print()`, ...).
    pub stdout: String,
    /// Captured conditions, in signal order (messages, warnings, custom
    /// conditions such as progress updates).
    pub conditions: Vec<RCondition>,
    /// Whether the task consumed random numbers (for the paper's
    /// "RNG used without seed = TRUE" misuse warning).
    pub rng_used: bool,
}

impl CaptureLog {
    pub fn is_empty(&self) -> bool {
        self.stdout.is_empty() && self.conditions.is_empty()
    }

    pub fn merge(&mut self, other: CaptureLog) {
        self.stdout.push_str(&other.stdout);
        self.conditions.extend(other.conditions);
        self.rng_used |= other.rng_used;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inherits_and_primary_class() {
        let c = RCondition::message_cond("hi");
        assert!(c.inherits("message"));
        assert!(c.inherits("condition"));
        assert!(!c.inherits("warning"));
        assert_eq!(c.primary_class(), "simpleMessage");
    }

    #[test]
    fn error_render_with_call() {
        let c = RCondition::error_cond("boom").with_call("f(x)");
        assert_eq!(c.render(), "Error in f(x): boom");
    }

    #[test]
    fn capture_log_merge() {
        let mut a = CaptureLog { stdout: "a".into(), ..Default::default() };
        let b = CaptureLog {
            stdout: "b".into(),
            conditions: vec![RCondition::warning_cond("w")],
            rng_used: true,
        };
        a.merge(b);
        assert_eq!(a.stdout, "ab");
        assert_eq!(a.conditions.len(), 1);
        assert!(a.rng_used);
    }

    #[test]
    fn serde_roundtrip() {
        let data =
            crate::wire::JsonValue::obj(vec![("amount", crate::wire::JsonValue::num(1.0))]);
        let c = RCondition::custom("progress", "step", Some(data));
        let s = crate::wire::to_string(&c).unwrap();
        let back: RCondition = crate::wire::from_str(&s).unwrap();
        assert_eq!(c, back);
    }
}
