//! Symbol interning.
//!
//! Identifiers are interned once — at parse time, at deserialization
//! time, or at the first `&str`-based env API call — into process-global
//! `Symbol(u32)` handles. Everything the evaluator does per call after
//! that (env frame lookups, parameter binding, builtin dispatch) is u32
//! comparison and indexing instead of string hashing, which is what makes
//! the per-element map loop cheap (ISSUE 4, tentpole layer 2).
//!
//! The interner is process-wide (symbols inside an [`Expr`] cross thread
//! boundaries with in-process backends) and append-only; interned strings
//! are leaked to `&'static str` so `as_str()` can hand out references
//! without holding the lock.
//!
//! **Tradeoff:** append-only interning means every *distinct binding
//! name* costs one permanent interner slot for the life of the process —
//! read paths probe without interning ([`Symbol::probe`]), but
//! `assign(paste0("v", i), ..)`-style data-dependent binding names grow
//! the interner by design (identifier sets are small and static in real
//! programs; a reclaiming interner would put refcount traffic on the
//! hottest lookup path). Worker task isolation is unaffected: interner
//! slots carry no values, only names.
//!
//! Builtin resolution is cached per symbol: the first unqualified lookup
//! of a symbol that misses the environment chain resolves against the
//! builtin registry and memoizes the `Option<BuiltinId>`, so steady-state
//! call dispatch (`sqrt(x)`, `x * 2`) never hashes a string again.
//!
//! [`Expr`]: super::ast::Expr

use std::collections::HashMap;
use std::fmt;
use std::sync::RwLock;

use once_cell::sync::Lazy;

use super::builtins::BuiltinId;

/// An interned identifier. Copyable, comparable and hashable as a plain
/// `u32`; resolves back to its text via the global interner.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Symbol(u32);

struct Interner {
    map: HashMap<&'static str, u32>,
    syms: Vec<&'static str>,
}

static INTERNER: Lazy<RwLock<Interner>> =
    Lazy::new(|| RwLock::new(Interner { map: HashMap::new(), syms: Vec::new() }));

/// Per-symbol memo of unqualified builtin resolution. Indexed by symbol
/// id; `None` = not resolved yet, `Some(x)` = resolved (x is the
/// registry answer, including "not a builtin"). Kept separate from the
/// interner lock so resolving (which touches the builtin registry
/// `Lazy`) never nests inside it.
static BUILTIN_CACHE: Lazy<RwLock<Vec<Option<Option<BuiltinId>>>>> =
    Lazy::new(|| RwLock::new(Vec::new()));

impl Symbol {
    /// Intern `s`, returning its stable process-wide handle.
    pub fn intern(s: &str) -> Symbol {
        if let Some(&id) = INTERNER.read().unwrap().map.get(s) {
            return Symbol(id);
        }
        let mut w = INTERNER.write().unwrap();
        // Re-check under the write lock (another thread may have won).
        if let Some(&id) = w.map.get(s) {
            return Symbol(id);
        }
        let leaked: &'static str = Box::leak(s.to_string().into_boxed_str());
        let id = w.syms.len() as u32;
        w.syms.push(leaked);
        w.map.insert(leaked, id);
        Symbol(id)
    }

    /// Read-only probe: the symbol for `s` if it was ever interned,
    /// without interning (and leaking) it. A name that was never
    /// interned cannot be bound in any environment, so read paths
    /// (`lookup`/`exists` by `&str`) use this to keep dynamic-name
    /// probes from growing the interner unboundedly.
    pub fn probe(s: &str) -> Option<Symbol> {
        INTERNER.read().unwrap().map.get(s).map(|&id| Symbol(id))
    }

    /// The interned text. `'static` because interned strings are leaked.
    pub fn as_str(self) -> &'static str {
        INTERNER.read().unwrap().syms[self.0 as usize]
    }

    /// Raw id (useful for dense side tables).
    pub fn id(self) -> u32 {
        self.0
    }

    /// Memoized unqualified builtin resolution for this symbol (the
    /// search-path answer of [`super::builtins::lookup_builtin`]).
    pub fn builtin_id(self) -> Option<BuiltinId> {
        {
            let cache = BUILTIN_CACHE.read().unwrap();
            if let Some(Some(resolved)) = cache.get(self.0 as usize) {
                return *resolved;
            }
        }
        // Resolve outside both locks, then memoize.
        let resolved = super::builtins::lookup_builtin(self.as_str()).map(|d| d.id);
        let mut cache = BUILTIN_CACHE.write().unwrap();
        if cache.len() <= self.0 as usize {
            cache.resize(self.0 as usize + 1, None);
        }
        cache[self.0 as usize] = Some(resolved);
        resolved
    }
}

/// The `...` symbol, pre-interned (hot in argument splicing).
pub fn sym_dots() -> Symbol {
    static DOTS: Lazy<Symbol> = Lazy::new(|| Symbol::intern("..."));
    *DOTS
}

impl fmt::Display for Symbol {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

impl fmt::Debug for Symbol {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:?}", self.as_str())
    }
}

impl From<&str> for Symbol {
    fn from(s: &str) -> Symbol {
        Symbol::intern(s)
    }
}

impl From<&String> for Symbol {
    fn from(s: &String) -> Symbol {
        Symbol::intern(s)
    }
}

impl From<String> for Symbol {
    fn from(s: String) -> Symbol {
        Symbol::intern(&s)
    }
}

impl PartialEq<str> for Symbol {
    fn eq(&self, other: &str) -> bool {
        self.as_str() == other
    }
}

impl PartialEq<&str> for Symbol {
    fn eq(&self, other: &&str) -> bool {
        self.as_str() == *other
    }
}

impl PartialEq<String> for Symbol {
    fn eq(&self, other: &String) -> bool {
        self.as_str() == other.as_str()
    }
}

impl PartialEq<Symbol> for str {
    fn eq(&self, other: &Symbol) -> bool {
        self == other.as_str()
    }
}

impl PartialEq<Symbol> for &str {
    fn eq(&self, other: &Symbol) -> bool {
        *self == other.as_str()
    }
}

impl PartialEq<Symbol> for String {
    fn eq(&self, other: &Symbol) -> bool {
        self.as_str() == other.as_str()
    }
}

// Symbols serialize as their text (wire format identical to the
// pre-interning `String` representation) and re-intern on decode, so
// ids never cross a process boundary.
impl serde::Serialize for Symbol {
    fn serialize<S: serde::Serializer>(&self, s: S) -> Result<S::Ok, S::Error> {
        s.serialize_str(self.as_str())
    }
}

impl<'de> serde::Deserialize<'de> for Symbol {
    fn deserialize<D: serde::Deserializer<'de>>(d: D) -> Result<Self, D::Error> {
        struct V;
        impl<'de> serde::de::Visitor<'de> for V {
            type Value = Symbol;
            fn expecting(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                f.write_str("an identifier string")
            }
            fn visit_str<E: serde::de::Error>(self, v: &str) -> Result<Symbol, E> {
                Ok(Symbol::intern(v))
            }
            fn visit_string<E: serde::de::Error>(self, v: String) -> Result<Symbol, E> {
                Ok(Symbol::intern(&v))
            }
        }
        d.deserialize_str(V)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intern_is_stable_and_deduplicating() {
        let a = Symbol::intern("alpha_sym_test");
        let b = Symbol::intern("alpha_sym_test");
        let c = Symbol::intern("beta_sym_test");
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(a.as_str(), "alpha_sym_test");
        assert_eq!(a.id(), b.id());
    }

    #[test]
    fn compares_against_strings() {
        let s = Symbol::intern("gamma_sym_test");
        assert!(s == "gamma_sym_test");
        assert!(s == "gamma_sym_test".to_string());
        assert!("gamma_sym_test" == s);
        assert!(s != "delta_sym_test");
    }

    #[test]
    fn builtin_resolution_memoized() {
        let s = Symbol::intern("sqrt");
        let first = s.builtin_id();
        assert!(first.is_some(), "sqrt must resolve to a builtin");
        assert_eq!(first, s.builtin_id());
        let miss = Symbol::intern("no_such_function_xyz");
        assert_eq!(miss.builtin_id(), None);
    }

    #[test]
    fn serde_roundtrips_as_text() {
        let s = Symbol::intern("wire_sym_test");
        let json = crate::wire::to_string(&s).unwrap();
        assert_eq!(json, "\"wire_sym_test\"");
        let back: Symbol = crate::wire::from_str(&json).unwrap();
        assert_eq!(s, back);
        let bytes = crate::wire::bin::to_bytes(&s).unwrap();
        let back2: Symbol = crate::wire::bin::from_bytes(&bytes).unwrap();
        assert_eq!(s, back2);
    }
}
