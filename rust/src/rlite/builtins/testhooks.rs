//! Failure-injection builtins backing the supervision conformance
//! suite (`futurize::` namespace, underscore-prefixed style).
//!
//! The no-hang guarantee — "a killed worker either recovers or raises a
//! `FutureError`, within a bounded wall clock" — can only be tested by
//! actually killing workers from inside a task. These hooks are
//! ordinary registered builtins (they ship in the binary like
//! `tools::pskill` ships in R), but they are *test hooks*: calling them
//! outside a kill-worker test tears down whatever executor runs them.
//!
//! - [`futurize_test_exit()`] hard-exits the current executor: in a
//!   worker *process* (multisession/cluster — `FUTURIZE_WORKER_IDX` is
//!   stamped at spawn) it is `exit(134)`, the OOM-kill analog; in a
//!   scheduler-owned job *thread* (batchtools_sim) it panics, killing
//!   just that executor thread — the dead-executor case the batchtools
//!   scheduler must detect.
//! - [`futurize_test_exit_once(path)`] same, but only for the first
//!   caller to claim the marker file at `path` — lets `retries = 1`
//!   tests crash exactly one attempt and let the resubmit succeed.
//! - [`futurize_test_desync()`] writes a well-framed but undecodable
//!   message to the process's *raw* stdout — i.e. into the middle of
//!   the worker protocol stream — to exercise the desync-is-a-worker-
//!   failure path.

use super::{Args, Reg};
use crate::rlite::env::EnvRef;
use crate::rlite::eval::{EvalResult, Interp, Signal};
use crate::rlite::value::RVal;

pub fn register(r: &mut Reg) {
    r.normal("futurize", "futurize_test_exit", test_exit_fn);
    r.normal("futurize", "futurize_test_exit_once", test_exit_once_fn);
    r.normal("futurize", "futurize_test_desync", test_desync_fn);
}

/// Die the way a crashed worker dies — without unwinding the task
/// runner or sending a `Done`.
fn hard_exit() -> ! {
    if std::env::var("FUTURIZE_WORKER_IDX").is_ok() {
        // A real worker subprocess: exit hard, like an OOM-kill.
        std::process::exit(134);
    }
    // An in-process executor thread (batchtools_sim job thread): take
    // down just this thread.
    panic!("futurize_test_exit: simulated executor death");
}

fn test_exit_fn(_i: &mut Interp, _args: Args, _env: &EnvRef) -> EvalResult {
    hard_exit()
}

fn test_exit_once_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let path = args.bind(&["path"]).req(0, "path")?.as_str().map_err(Signal::error)?;
    // create_new is an atomic claim: exactly one attempt dies, even if
    // the chunk is raced or resubmitted across fresh worker processes.
    match std::fs::OpenOptions::new().write(true).create_new(true).open(&path) {
        Ok(_) => hard_exit(),
        Err(_) => Ok(RVal::Null),
    }
}

fn test_desync_fn(_i: &mut Interp, _args: Args, _env: &EnvRef) -> EvalResult {
    use std::io::Write;
    // Bypass the task runner's stdout capture on purpose: in a worker
    // process the raw fd *is* the protocol channel. The payload is a
    // valid frame (so the parent's reader stays length-aligned and
    // fails fast in decode) that no codec accepts: 0xFF/0xFE lead bytes
    // are an over-long varint enum tag in binary and not JSON either.
    let mut out = std::io::stdout().lock();
    let _ = crate::wire::codec::write_frame(&mut out, b"\xff\xfe futurize-desync");
    let _ = out.flush();
    Ok(RVal::Null)
}
