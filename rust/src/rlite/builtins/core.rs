//! Core base-R builtins: vectors, lists, coercions, structural helpers.

use super::{Args, Reg};
use crate::rlite::env::{self, Env, EnvRef};
use crate::rlite::eval::{EvalResult, Interp, Signal};
use crate::rlite::value::{RList, RVal, RVec};

pub fn register(r: &mut Reg) {
    r.normal("base", "c", c_fn);
    // `( expr )` — parenthesis kept as a call node so the futurize
    // transpiler can unwrap it (paper §3.3); semantically identity.
    r.normal("base", "(", |_i, a, _e| a.bind(&["x"]).req(0, "x"));
    // cbind over equal-length vectors: concatenated column-major (our
    // matrix model is a flat column-major vector / list of columns).
    r.normal("base", "cbind", c_fn);
    r.normal("base", "rbind", c_fn);
    r.normal("base", "list", list_fn);
    r.normal("base", "length", length_fn);
    r.normal("base", "names", names_fn);
    r.normal("base", "rev", rev_fn);
    r.normal("base", "unlist", unlist_fn);
    r.normal("base", "seq", seq_fn);
    r.normal("base", "seq_len", seq_len_fn);
    r.normal("base", "seq_along", seq_along_fn);
    r.normal("base", "rep", rep_fn);
    r.normal("base", "identity", identity_fn);
    r.normal("base", "I", identity_fn);
    r.normal("base", "invisible", identity_fn);
    r.normal("base", "class", class_fn);
    r.normal("base", "inherits", inherits_fn);
    r.normal("base", "is.null", is_null_fn);
    r.normal("base", "is.function", is_function_fn);
    r.normal("base", "is.numeric", is_numeric_fn);
    r.normal("base", "is.character", is_character_fn);
    r.normal("base", "is.list", is_list_fn);
    r.normal("base", "is.na", is_na_fn);
    r.normal("base", "as.numeric", as_numeric_fn);
    r.normal("base", "as.double", as_numeric_fn);
    r.normal("base", "as.integer", as_integer_fn);
    r.normal("base", "as.character", as_character_fn);
    r.normal("base", "as.logical", as_logical_fn);
    r.normal("base", "as.list", as_list_fn);
    r.normal("base", "as.vector", identity_fn);
    r.normal("base", "numeric", numeric_fn);
    r.normal("base", "integer", integer_fn);
    r.normal("base", "character", character_fn);
    r.normal("base", "logical", logical_fn);
    r.normal("base", "vector", vector_fn);
    r.normal("base", "paste", paste_fn);
    r.normal("base", "paste0", paste0_fn);
    r.normal("base", "nchar", nchar_fn);
    r.normal("base", "toupper", toupper_fn);
    r.normal("base", "tolower", tolower_fn);
    r.normal("base", "strsplit", strsplit_fn);
    r.normal("base", "gsub", gsub_fn);
    r.normal("base", "sprintf", sprintf_fn);
    r.normal("base", "data.frame", data_frame_fn);
    r.normal("base", "nrow", nrow_fn);
    r.normal("base", "ncol", ncol_fn);
    r.normal("base", "head", head_fn);
    r.normal("base", "tail", tail_fn);
    r.normal("base", "which", which_fn);
    r.normal("base", "any", any_fn);
    r.normal("base", "all", all_fn);
    r.normal("base", "identical", identical_fn);
    r.normal("base", "stopifnot", stopifnot_fn);
    r.normal("base", "do.call", do_call_fn);
    r.normal("base", "Reduce", reduce_fn);
    r.normal("base", "append", append_fn);
    r.normal("base", "setdiff", setdiff_fn);
    r.normal("base", "unique", unique_fn);
    r.normal("base", "sort", sort_fn);
    r.normal("base", "order", order_fn);
    r.normal("base", "exists", exists_fn);
    r.normal("base", "get", get_fn);
    r.normal("base", "environment", environment_fn);
    r.normal("base", "new.env", new_env_fn);
    r.normal("base", "structure", structure_fn);
    r.normal("base", "attr", attr_fn);
    r.normal("base", "max", max_fn);
    r.normal("base", "min", min_fn);
    r.normal("base", "matrix", matrix_fn);
    r.normal("base", "tabulate", tabulate_fn);
}

/// `tabulate(bin, nbins)`: counts of integer values 1..nbins. Native —
/// the interpreted `for (k in idx) w[k] <- w[k] + 1` loop this replaces
/// was the hot spot of `boot(stype = "w")` (EXPERIMENTS.md §Perf).
fn tabulate_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["bin", "nbins"]);
    let bin = b.req(0, "bin")?.as_dbl_vec().map_err(Signal::error)?;
    let nbins = match b.opt(1) {
        Some(v) => v.as_usize().map_err(Signal::error)?,
        None => bin.iter().cloned().fold(0.0, f64::max).max(0.0) as usize,
    };
    let mut counts = vec![0.0; nbins];
    for &v in &bin {
        let k = v as i64;
        if k >= 1 && (k as usize) <= nbins {
            counts[k as usize - 1] += 1.0;
        }
    }
    Ok(RVal::dbl(counts))
}

// -- vector construction ------------------------------------------------------

/// `c(...)`: concatenate with R's coercion hierarchy
/// (list > character > double > integer > logical).
pub fn c_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    combine(args.items)
}

pub fn combine(items: Vec<(Option<String>, RVal)>) -> EvalResult {
    // Determine result kind.
    let mut has_list = false;
    let mut has_chr = false;
    let mut has_dbl = false;
    let mut any_names = false;
    for (n, v) in &items {
        match v {
            RVal::List(_) | RVal::Closure(_) | RVal::Builtin(_) | RVal::Cond(_) | RVal::Env(_) => {
                has_list = true
            }
            RVal::Chr(_) => has_chr = true,
            RVal::Dbl(_) | RVal::Int(_) | RVal::Lgl(_) => has_dbl = true,
            RVal::Null => {}
        }
        if n.is_some() || v.names().is_some() {
            any_names = true;
        }
    }
    let _ = has_dbl;
    let mut names: Vec<String> = Vec::new();
    let push_names = |names: &mut Vec<String>, outer: &Option<String>, v: &RVal, k: usize| {
        for j in 0..k {
            let inner = v.names().and_then(|ns| ns.get(j).cloned()).unwrap_or_default();
            let label = match (outer, inner.is_empty()) {
                (Some(o), false) => format!("{o}.{inner}"),
                (Some(o), true) => {
                    if k == 1 {
                        o.clone()
                    } else {
                        format!("{o}{}", j + 1)
                    }
                }
                (None, _) => inner,
            };
            names.push(label);
        }
    };

    if has_list {
        let mut vals = Vec::new();
        for (n, v) in &items {
            match v {
                RVal::Null => {}
                RVal::List(l) => {
                    push_names(&mut names, n, v, l.len());
                    vals.extend(l.vals.iter().cloned());
                }
                other => {
                    push_names(&mut names, n, v, 1);
                    vals.push(other.clone());
                }
            }
        }
        let mut out = RList::plain(vals);
        if any_names {
            out.names = Some(names);
        }
        return Ok(RVal::List(out));
    }
    if has_chr {
        let mut vals = Vec::new();
        for (n, v) in &items {
            let s = v.as_str_vec().map_err(Signal::error)?;
            push_names(&mut names, n, v, s.len());
            vals.extend(s);
        }
        return Ok(RVal::Chr(RVec::with_names(vals, if any_names { Some(names) } else { None })));
    }
    // All-logical stays logical (R's coercion hierarchy).
    let all_lgl = items.iter().all(|(_, v)| matches!(v, RVal::Lgl(_) | RVal::Null));
    if all_lgl {
        let mut vals = Vec::new();
        for (n, v) in &items {
            if let RVal::Lgl(b) = v {
                push_names(&mut names, n, v, b.len());
                vals.extend(b.vals.iter().copied());
            }
        }
        return Ok(RVal::Lgl(RVec::with_names(vals, if any_names { Some(names) } else { None })));
    }
    let mut vals = Vec::new();
    for (n, v) in &items {
        let d = v.as_dbl_vec().map_err(Signal::error)?;
        push_names(&mut names, n, v, d.len());
        vals.extend(d);
    }
    Ok(RVal::Dbl(RVec::with_names(vals, if any_names { Some(names) } else { None })))
}

fn list_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let any_named = args.items.iter().any(|(n, _)| n.is_some());
    let names: Vec<String> =
        args.items.iter().map(|(n, _)| n.clone().unwrap_or_default()).collect();
    let vals: Vec<RVal> = args.items.into_iter().map(|(_, v)| v).collect();
    let mut l = RList::plain(vals);
    if any_named {
        l.names = Some(names);
    }
    Ok(RVal::List(l))
}

fn length_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["x"]);
    Ok(RVal::scalar_int(b.req(0, "x")?.len() as i64))
}

fn names_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["x"]);
    match b.req(0, "x")?.names() {
        Some(ns) => Ok(RVal::chr(ns.to_vec())),
        None => Ok(RVal::Null),
    }
}

fn rev_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["x"]);
    let x = b.req(0, "x")?;
    Ok(match x {
        RVal::Dbl(mut v) => {
            v.vals_mut().reverse();
            if let Some(n) = &mut v.names {
                n.reverse();
            }
            RVal::Dbl(v)
        }
        RVal::Int(mut v) => {
            v.vals_mut().reverse();
            if let Some(n) = &mut v.names {
                n.reverse();
            }
            RVal::Int(v)
        }
        RVal::Chr(mut v) => {
            v.vals_mut().reverse();
            if let Some(n) = &mut v.names {
                n.reverse();
            }
            RVal::Chr(v)
        }
        RVal::Lgl(mut v) => {
            v.vals_mut().reverse();
            if let Some(n) = &mut v.names {
                n.reverse();
            }
            RVal::Lgl(v)
        }
        RVal::List(mut l) => {
            l.vals.reverse();
            if let Some(n) = &mut l.names {
                n.reverse();
            }
            RVal::List(l)
        }
        other => other,
    })
}

fn unlist_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["x"]);
    let x = b.req(0, "x")?;
    match x {
        RVal::List(l) => {
            let items: Vec<(Option<String>, RVal)> = l
                .vals
                .into_iter()
                .enumerate()
                .map(|(i, v)| {
                    let nm = l
                        .names
                        .as_ref()
                        .and_then(|ns| ns.get(i))
                        .filter(|s| !s.is_empty())
                        .cloned();
                    (nm, v)
                })
                .collect();
            combine(items)
        }
        other => Ok(other),
    }
}

fn seq_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["from", "to", "by", "length.out"]);
    let from = b.opt(0).map(|v| v.as_f64()).transpose().map_err(Signal::error)?.unwrap_or(1.0);
    let to = b.opt(1).map(|v| v.as_f64()).transpose().map_err(Signal::error)?;
    let by = b.opt(2).map(|v| v.as_f64()).transpose().map_err(Signal::error)?;
    let len_out =
        b.opt(3).map(|v| v.as_usize()).transpose().map_err(Signal::error)?;
    match (to, by, len_out) {
        (Some(to), None, None) => {
            let step = if to >= from { 1.0 } else { -1.0 };
            Ok(RVal::dbl(arange(from, to, step)))
        }
        (Some(to), Some(by), _) => Ok(RVal::dbl(arange(from, to, by))),
        (Some(to), None, Some(n)) => {
            if n == 1 {
                return Ok(RVal::dbl(vec![from]));
            }
            let step = (to - from) / (n as f64 - 1.0);
            Ok(RVal::dbl((0..n).map(|k| from + step * k as f64).collect()))
        }
        (None, _, Some(n)) => Ok(RVal::dbl((1..=n).map(|k| k as f64).collect())),
        _ => Ok(RVal::dbl(arange(1.0, from, 1.0))),
    }
}

fn arange(from: f64, to: f64, by: f64) -> Vec<f64> {
    let mut out = Vec::new();
    let mut x = from;
    if by > 0.0 {
        while x <= to + 1e-12 {
            out.push(x);
            x += by;
        }
    } else if by < 0.0 {
        while x >= to - 1e-12 {
            out.push(x);
            x += by;
        }
    }
    out
}

fn seq_len_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let n = args.bind(&["length.out"]).req(0, "length.out")?.as_usize().map_err(Signal::error)?;
    Ok(RVal::int((1..=n as i64).collect()))
}

fn seq_along_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let x = args.bind(&["along.with"]).req(0, "along.with")?;
    Ok(RVal::int((1..=x.len() as i64).collect()))
}

fn rep_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["x", "times", "each"]);
    let x = b.req(0, "x")?;
    let times = b.opt(1).map(|v| v.as_usize()).transpose().map_err(Signal::error)?.unwrap_or(1);
    let each = b.opt(2).map(|v| v.as_usize()).transpose().map_err(Signal::error)?.unwrap_or(1);
    let elems = x.iter_elements();
    let mut out: Vec<RVal> = Vec::with_capacity(elems.len() * times * each);
    for _ in 0..times {
        for e in &elems {
            for _ in 0..each {
                out.push(e.clone());
            }
        }
    }
    combine(out.into_iter().map(|v| (None, v)).collect())
}

fn identity_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    args.bind(&["x"]).req(0, "x")
}

// -- type predicates / coercions ----------------------------------------------

fn class_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    Ok(RVal::scalar_str(args.bind(&["x"]).req(0, "x")?.class()))
}

fn inherits_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["x", "what"]);
    let x = b.req(0, "x")?;
    let what = b.req(1, "what")?.as_str_vec().map_err(Signal::error)?;
    let hit = match &x {
        RVal::Cond(c) => what.iter().any(|w| c.inherits(w)),
        other => what.iter().any(|w| w == other.class()),
    };
    Ok(RVal::scalar_bool(hit))
}

fn is_null_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    Ok(RVal::scalar_bool(args.bind(&["x"]).req(0, "x")?.is_null()))
}

fn is_function_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    Ok(RVal::scalar_bool(args.bind(&["x"]).req(0, "x")?.is_function()))
}

fn is_numeric_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let x = args.bind(&["x"]).req(0, "x")?;
    Ok(RVal::scalar_bool(matches!(x, RVal::Dbl(_) | RVal::Int(_))))
}

fn is_character_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let x = args.bind(&["x"]).req(0, "x")?;
    Ok(RVal::scalar_bool(matches!(x, RVal::Chr(_))))
}

fn is_list_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let x = args.bind(&["x"]).req(0, "x")?;
    Ok(RVal::scalar_bool(matches!(x, RVal::List(_))))
}

fn is_na_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let x = args.bind(&["x"]).req(0, "x")?;
    match x {
        RVal::Dbl(v) => Ok(RVal::lgl(v.vals.iter().map(|x| x.is_nan()).collect())),
        other => Ok(RVal::lgl(vec![false; other.len()])),
    }
}

fn as_numeric_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let x = args.bind(&["x"]).req(0, "x")?;
    match &x {
        RVal::Chr(v) => {
            let vals: Vec<f64> =
                v.vals.iter().map(|s| s.parse::<f64>().unwrap_or(f64::NAN)).collect();
            Ok(RVal::dbl(vals))
        }
        _ => Ok(RVal::dbl(x.as_dbl_vec().map_err(Signal::error)?)),
    }
}

fn as_integer_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let x = args.bind(&["x"]).req(0, "x")?;
    let d = x.as_dbl_vec().map_err(Signal::error)?;
    Ok(RVal::int(d.into_iter().map(|x| x as i64).collect()))
}

fn as_character_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let x = args.bind(&["x"]).req(0, "x")?;
    Ok(RVal::chr(x.as_str_vec().map_err(Signal::error)?))
}

fn as_logical_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let x = args.bind(&["x"]).req(0, "x")?;
    let d = x.as_dbl_vec().map_err(Signal::error)?;
    Ok(RVal::lgl(d.into_iter().map(|x| x != 0.0).collect()))
}

fn as_list_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let x = args.bind(&["x"]).req(0, "x")?;
    let names = x.element_names();
    let vals = x.iter_elements();
    let mut l = RList::plain(vals);
    l.names = names;
    Ok(RVal::List(l))
}

fn numeric_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let n = args
        .bind(&["length"])
        .opt(0)
        .map(|v| v.as_usize())
        .transpose()
        .map_err(Signal::error)?
        .unwrap_or(0);
    Ok(RVal::dbl(vec![0.0; n]))
}

fn integer_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let n = args
        .bind(&["length"])
        .opt(0)
        .map(|v| v.as_usize())
        .transpose()
        .map_err(Signal::error)?
        .unwrap_or(0);
    Ok(RVal::int(vec![0; n]))
}

fn character_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let n = args
        .bind(&["length"])
        .opt(0)
        .map(|v| v.as_usize())
        .transpose()
        .map_err(Signal::error)?
        .unwrap_or(0);
    Ok(RVal::chr(vec![String::new(); n]))
}

fn logical_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let n = args
        .bind(&["length"])
        .opt(0)
        .map(|v| v.as_usize())
        .transpose()
        .map_err(Signal::error)?
        .unwrap_or(0);
    Ok(RVal::lgl(vec![false; n]))
}

fn vector_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["mode", "length"]);
    let mode = b
        .opt(0)
        .map(|v| v.as_str())
        .transpose()
        .map_err(Signal::error)?
        .unwrap_or_else(|| "logical".into());
    let n = b.opt(1).map(|v| v.as_usize()).transpose().map_err(Signal::error)?.unwrap_or(0);
    Ok(match mode.as_str() {
        "numeric" | "double" => RVal::dbl(vec![0.0; n]),
        "integer" => RVal::int(vec![0; n]),
        "character" => RVal::chr(vec![String::new(); n]),
        "list" => RVal::list(vec![RVal::Null; n]),
        _ => RVal::lgl(vec![false; n]),
    })
}

// -- strings -------------------------------------------------------------------

fn paste_impl(args: &Args, default_sep: &str) -> EvalResult {
    let sep = args
        .named("sep")
        .map(|v| v.as_str())
        .transpose()
        .map_err(Signal::error)?
        .unwrap_or_else(|| default_sep.to_string());
    let collapse = args.named("collapse").cloned();
    let parts: Vec<Vec<String>> = args
        .items
        .iter()
        .filter(|(n, _)| n.as_deref() != Some("sep") && n.as_deref() != Some("collapse"))
        .map(|(_, v)| v.as_str_vec().map_err(Signal::error))
        .collect::<Result<_, _>>()?;
    let n = parts.iter().map(|p| p.len()).max().unwrap_or(0);
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let row: Vec<String> = parts
            .iter()
            .filter(|p| !p.is_empty())
            .map(|p| p[i % p.len()].clone())
            .collect();
        out.push(row.join(&sep));
    }
    match collapse {
        Some(RVal::Chr(cv)) if !cv.vals.is_empty() => {
            Ok(RVal::scalar_str(out.join(&cv.vals[0])))
        }
        _ => Ok(RVal::chr(out)),
    }
}

fn paste_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    paste_impl(&args, " ")
}

fn paste0_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    paste_impl(&args, "")
}

fn nchar_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let x = args.bind(&["x"]).req(0, "x")?.as_str_vec().map_err(Signal::error)?;
    Ok(RVal::int(x.iter().map(|s| s.chars().count() as i64).collect()))
}

fn toupper_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let x = args.bind(&["x"]).req(0, "x")?.as_str_vec().map_err(Signal::error)?;
    Ok(RVal::chr(x.iter().map(|s| s.to_uppercase()).collect()))
}

fn tolower_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let x = args.bind(&["x"]).req(0, "x")?.as_str_vec().map_err(Signal::error)?;
    Ok(RVal::chr(x.iter().map(|s| s.to_lowercase()).collect()))
}

fn strsplit_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["x", "split"]);
    let x = b.req(0, "x")?.as_str_vec().map_err(Signal::error)?;
    let split = b.req(1, "split")?.as_str().map_err(Signal::error)?;
    let out: Vec<RVal> = x
        .iter()
        .map(|s| {
            let parts: Vec<String> = if split.is_empty() {
                s.chars().map(|c| c.to_string()).collect()
            } else {
                s.split(&split).map(|p| p.to_string()).collect()
            };
            RVal::chr(parts)
        })
        .collect();
    Ok(RVal::list(out))
}

fn gsub_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["pattern", "replacement", "x"]);
    let pat = b.req(0, "pattern")?.as_str().map_err(Signal::error)?;
    let rep = b.req(1, "replacement")?.as_str().map_err(Signal::error)?;
    let x = b.req(2, "x")?.as_str_vec().map_err(Signal::error)?;
    // Literal (fixed) replacement — enough for the tm-style examples.
    Ok(RVal::chr(x.iter().map(|s| s.replace(&pat, &rep)).collect()))
}

fn sprintf_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let pos = args.positional();
    let fmt = pos
        .first()
        .ok_or_else(|| Signal::error("sprintf needs a format"))?
        .as_str()
        .map_err(Signal::error)?;
    let mut out = String::new();
    let mut ai = 1usize;
    let mut chars = fmt.chars().peekable();
    while let Some(c) = chars.next() {
        if c != '%' {
            out.push(c);
            continue;
        }
        let mut spec = String::from("%");
        loop {
            match chars.next() {
                Some(k) => {
                    spec.push(k);
                    if k.is_ascii_alphabetic() || k == '%' {
                        break;
                    }
                }
                None => return Err(Signal::error("bad sprintf format")),
            }
        }
        let conv = spec.chars().last().unwrap();
        match conv {
            '%' => out.push('%'),
            'd' | 'i' => {
                let v = pos.get(ai).ok_or_else(|| Signal::error("too few sprintf args"))?;
                out.push_str(&format!("{}", v.as_i64().map_err(Signal::error)?));
                ai += 1;
            }
            'f' | 'g' | 'e' => {
                let v = pos.get(ai).ok_or_else(|| Signal::error("too few sprintf args"))?;
                let x = v.as_f64().map_err(Signal::error)?;
                // honour %.Nf
                if let Some(dot) = spec.find('.') {
                    let prec: usize =
                        spec[dot + 1..spec.len() - 1].parse().unwrap_or(6);
                    out.push_str(&format!("{:.*}", prec, x));
                } else {
                    out.push_str(&crate::rlite::value::format_dbl(x));
                }
                ai += 1;
            }
            's' => {
                let v = pos.get(ai).ok_or_else(|| Signal::error("too few sprintf args"))?;
                out.push_str(&v.as_str_vec().map_err(Signal::error)?.join(","));
                ai += 1;
            }
            other => return Err(Signal::error(format!("unsupported sprintf conversion %{other}"))),
        }
    }
    Ok(RVal::scalar_str(out))
}

// -- data.frame-ish -------------------------------------------------------------

fn data_frame_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let mut names = Vec::new();
    let mut cols = Vec::new();
    let mut nrow = 0usize;
    for (n, v) in &args.items {
        let name = n.clone().unwrap_or_else(|| format!("V{}", names.len() + 1));
        nrow = nrow.max(v.len());
        names.push(name);
        cols.push(v.clone());
    }
    // Recycle length-1 columns.
    for c in cols.iter_mut() {
        if c.len() == 1 && nrow > 1 {
            let elems = c.iter_elements();
            let rep: Vec<RVal> = (0..nrow).map(|_| elems[0].clone()).collect();
            *c = combine(rep.into_iter().map(|v| (None, v)).collect())?;
        }
    }
    let mut l = RList::named(cols, names);
    l.class = Some("data.frame".into());
    Ok(RVal::List(l))
}

fn nrow_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let x = args.bind(&["x"]).req(0, "x")?;
    match &x {
        RVal::List(l) if l.class.as_deref() == Some("data.frame") => {
            Ok(RVal::scalar_int(l.vals.first().map(|c| c.len()).unwrap_or(0) as i64))
        }
        _ => Ok(RVal::Null),
    }
}

fn ncol_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let x = args.bind(&["x"]).req(0, "x")?;
    match &x {
        RVal::List(l) if l.class.as_deref() == Some("data.frame") => {
            Ok(RVal::scalar_int(l.len() as i64))
        }
        _ => Ok(RVal::Null),
    }
}

fn head_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["x", "n"]);
    let x = b.req(0, "x")?;
    let n = b.opt(1).map(|v| v.as_usize()).transpose().map_err(Signal::error)?.unwrap_or(6);
    let elems = x.iter_elements();
    let take: Vec<RVal> = elems.into_iter().take(n).collect();
    match x {
        RVal::List(_) => Ok(RVal::list(take)),
        _ => combine(take.into_iter().map(|v| (None, v)).collect()),
    }
}

fn tail_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["x", "n"]);
    let x = b.req(0, "x")?;
    let n = b.opt(1).map(|v| v.as_usize()).transpose().map_err(Signal::error)?.unwrap_or(6);
    let elems = x.iter_elements();
    let skip = elems.len().saturating_sub(n);
    let take: Vec<RVal> = elems.into_iter().skip(skip).collect();
    match x {
        RVal::List(_) => Ok(RVal::list(take)),
        _ => combine(take.into_iter().map(|v| (None, v)).collect()),
    }
}

// -- logic / search ---------------------------------------------------------------

fn which_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let x = args.bind(&["x"]).req(0, "x")?;
    match x {
        RVal::Lgl(v) => Ok(RVal::int(
            v.vals
                .iter()
                .enumerate()
                .filter(|(_, &b)| b)
                .map(|(i, _)| (i + 1) as i64)
                .collect(),
        )),
        other => Err(Signal::error(format!("which() expects logical, got {}", other.class()))),
    }
}

fn any_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let mut hit = false;
    for (_, v) in &args.items {
        for e in v.as_dbl_vec().map_err(Signal::error)? {
            hit |= e != 0.0;
        }
    }
    Ok(RVal::scalar_bool(hit))
}

fn all_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let mut ok = true;
    for (_, v) in &args.items {
        for e in v.as_dbl_vec().map_err(Signal::error)? {
            ok &= e != 0.0;
        }
    }
    Ok(RVal::scalar_bool(ok))
}

fn identical_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["x", "y"]);
    Ok(RVal::scalar_bool(b.req(0, "x")? == b.req(1, "y")?))
}

fn stopifnot_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    for (name, v) in &args.items {
        let d = v.as_dbl_vec().map_err(Signal::error)?;
        if d.is_empty() || d.iter().any(|&x| x == 0.0) {
            let what = name.clone().unwrap_or_else(|| "condition".into());
            return Err(Signal::error(format!("{what} is not TRUE")));
        }
    }
    Ok(RVal::Null)
}

fn do_call_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let b = args.bind(&["what", "args"]);
    let what = b.req(0, "what")?;
    let f = match &what {
        RVal::Chr(_) => {
            let name = what.as_str().map_err(Signal::error)?;
            env::lookup(env, &name)
                .or_else(|| super::lookup_builtin(&name).map(|d| RVal::Builtin(d.id)))
                .ok_or_else(|| Signal::error(format!("could not find function \"{name}\"")))?
        }
        other => other.clone(),
    };
    let arg_list = match b.req(1, "args")? {
        RVal::List(l) => {
            let names = l.names.clone();
            l.vals
                .into_iter()
                .enumerate()
                .map(|(idx, v)| {
                    let nm = names
                        .as_ref()
                        .and_then(|ns| ns.get(idx))
                        .filter(|s| !s.is_empty())
                        .cloned();
                    (nm, v)
                })
                .collect()
        }
        RVal::Null => vec![],
        other => vec![(None, other)],
    };
    i.call_function(&f, arg_list, env)
}

fn reduce_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let b = args.bind(&["f", "x", "init", "accumulate"]);
    let f = b.req(0, "f")?;
    let x = b.req(1, "x")?;
    let init = b.opt(2);
    let mut elems = x.iter_elements().into_iter();
    let mut acc = match init {
        Some(v) if !v.is_null() => v,
        _ => match elems.next() {
            Some(v) => v,
            None => return Ok(RVal::Null),
        },
    };
    for e in elems {
        acc = i.call_function(&f, vec![(None, acc), (None, e)], env)?;
    }
    Ok(acc)
}

fn append_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["x", "values"]);
    combine(vec![(None, b.req(0, "x")?), (None, b.req(1, "values")?)])
}

fn setdiff_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["x", "y"]);
    let x = b.req(0, "x")?.as_str_vec().map_err(Signal::error)?;
    let y = b.req(1, "y")?.as_str_vec().map_err(Signal::error)?;
    Ok(RVal::chr(x.into_iter().filter(|e| !y.contains(e)).collect()))
}

fn unique_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let x = args.bind(&["x"]).req(0, "x")?;
    match x {
        RVal::Chr(v) => {
            let mut seen = std::collections::HashSet::new();
            Ok(RVal::chr(v.take_vals().into_iter().filter(|s| seen.insert(s.clone())).collect()))
        }
        other => {
            let d = other.as_dbl_vec().map_err(Signal::error)?;
            let mut seen = Vec::new();
            for x in d {
                if !seen.iter().any(|&s: &f64| s == x) {
                    seen.push(x);
                }
            }
            Ok(RVal::dbl(seen))
        }
    }
}

fn sort_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["x", "decreasing"]);
    let decreasing =
        b.opt(1).map(|v| v.as_bool()).transpose().map_err(Signal::error)?.unwrap_or(false);
    let x = b.req(0, "x")?;
    match x {
        RVal::Chr(mut v) => {
            let vals = v.vals_mut();
            vals.sort();
            if decreasing {
                vals.reverse();
            }
            v.names = None;
            Ok(RVal::Chr(v))
        }
        other => {
            let mut d = other.as_dbl_vec().map_err(Signal::error)?;
            d.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            if decreasing {
                d.reverse();
            }
            Ok(RVal::dbl(d))
        }
    }
}

fn order_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let x = args.bind(&["x"]).req(0, "x")?;
    let d = x.as_dbl_vec().map_err(Signal::error)?;
    let mut idx: Vec<usize> = (0..d.len()).collect();
    idx.sort_by(|&a, &b| d[a].partial_cmp(&d[b]).unwrap_or(std::cmp::Ordering::Equal));
    Ok(RVal::int(idx.into_iter().map(|i| (i + 1) as i64).collect()))
}

// -- environments ---------------------------------------------------------------

fn exists_fn(_i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let name = args.bind(&["x"]).req(0, "x")?.as_str().map_err(Signal::error)?;
    Ok(RVal::scalar_bool(
        env::exists(env, &name) || super::lookup_builtin(&name).is_some(),
    ))
}

fn get_fn(_i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let b = args.bind(&["x", "envir"]);
    let name = b.req(0, "x")?.as_str().map_err(Signal::error)?;
    let target = match b.opt(1) {
        Some(RVal::Env(e)) => e,
        _ => env.clone(),
    };
    env::lookup(&target, &name)
        .or_else(|| super::lookup_builtin(&name).map(|d| RVal::Builtin(d.id)))
        .ok_or_else(|| Signal::error(format!("object '{name}' not found")))
}

fn environment_fn(_i: &mut Interp, _args: Args, env: &EnvRef) -> EvalResult {
    Ok(RVal::Env(env.clone()))
}

fn new_env_fn(_i: &mut Interp, _args: Args, env: &EnvRef) -> EvalResult {
    Ok(RVal::Env(Env::child_of(env)))
}

fn structure_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["x", "class"]);
    let mut x = b.req(0, "x")?;
    if let (RVal::List(l), Some(cls)) = (&mut x, b.opt(1)) {
        l.class = Some(cls.as_str().map_err(Signal::error)?);
    }
    Ok(x)
}

fn attr_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["x", "which"]);
    let x = b.req(0, "x")?;
    let which = b.req(1, "which")?.as_str().map_err(Signal::error)?;
    match which.as_str() {
        "names" => match x.names() {
            Some(ns) => Ok(RVal::chr(ns.to_vec())),
            None => Ok(RVal::Null),
        },
        "class" => Ok(RVal::scalar_str(x.class())),
        _ => Ok(RVal::Null),
    }
}

fn max_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let mut m = f64::NEG_INFINITY;
    for (_, v) in &args.items {
        for x in v.as_dbl_vec().map_err(Signal::error)? {
            m = m.max(x);
        }
    }
    Ok(RVal::scalar_dbl(m))
}

fn min_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let mut m = f64::INFINITY;
    for (_, v) in &args.items {
        for x in v.as_dbl_vec().map_err(Signal::error)? {
            m = m.min(x);
        }
    }
    Ok(RVal::scalar_dbl(m))
}

/// Minimal `matrix()`: stored as a list of column vectors with a
/// `"matrix"` class tag (enough for the glmnet/caret-style examples).
fn matrix_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["data", "nrow", "ncol"]);
    let data = b.req(0, "data")?.as_dbl_vec().map_err(Signal::error)?;
    let nrow = b.opt(1).map(|v| v.as_usize()).transpose().map_err(Signal::error)?;
    let ncol = b.opt(2).map(|v| v.as_usize()).transpose().map_err(Signal::error)?;
    let (nr, nc) = match (nrow, ncol) {
        (Some(r), Some(c)) => (r, c),
        (Some(r), None) => (r, data.len().div_ceil(r.max(1))),
        (None, Some(c)) => (data.len().div_ceil(c.max(1)), c),
        (None, None) => (data.len(), 1),
    };
    let mut cols = Vec::with_capacity(nc);
    for j in 0..nc {
        let mut col = Vec::with_capacity(nr);
        for i in 0..nr {
            let idx = j * nr + i;
            col.push(if data.is_empty() { 0.0 } else { data[idx % data.len()] });
        }
        cols.push(RVal::dbl(col));
    }
    let mut l = RList::plain(cols);
    l.class = Some("matrix".into());
    Ok(RVal::List(l))
}

#[cfg(test)]
mod tests {
    use crate::rlite::eval::Interp;
    use crate::rlite::value::RVal;

    fn run(src: &str) -> RVal {
        Interp::new().eval_program(src).unwrap_or_else(|e| panic!("{src}: {e:?}"))
    }

    #[test]
    fn c_concatenates_and_coerces() {
        assert_eq!(run("c(1, 2, 3)"), RVal::dbl(vec![1.0, 2.0, 3.0]));
        assert_eq!(
            run("c(1, \"a\")").as_str_vec().unwrap(),
            vec!["1".to_string(), "a".to_string()]
        );
    }

    #[test]
    fn c_preserves_names() {
        let v = run("c(a = 1, b = 2)");
        assert_eq!(v.names().unwrap(), &["a".to_string(), "b".to_string()]);
    }

    #[test]
    fn seq_variants() {
        assert_eq!(run("seq_len(3)"), RVal::int(vec![1, 2, 3]));
        assert_eq!(run("seq(2, 8, by = 2)"), RVal::dbl(vec![2.0, 4.0, 6.0, 8.0]));
        assert_eq!(run("seq_along(c(9, 9))"), RVal::int(vec![1, 2]));
    }

    #[test]
    fn rep_times_each() {
        assert_eq!(run("rep(1:2, times = 2)"), RVal::dbl(vec![1.0, 2.0, 1.0, 2.0]));
        assert_eq!(run("rep(1:2, each = 2)"), RVal::dbl(vec![1.0, 1.0, 2.0, 2.0]));
    }

    #[test]
    fn paste_family() {
        assert_eq!(run("paste(\"a\", \"b\")"), RVal::chr(vec!["a b".into()]));
        assert_eq!(run("paste0(\"x = \", 1)"), RVal::chr(vec!["x = 1".into()]));
        assert_eq!(
            run("paste(c(\"a\",\"b\"), collapse = \"+\")"),
            RVal::scalar_str("a+b")
        );
    }

    #[test]
    fn do_call_by_name() {
        assert_eq!(run("do.call(\"sum\", list(1, 2, 3))"), RVal::scalar_dbl(6.0));
    }

    #[test]
    fn reduce_folds() {
        assert_eq!(
            run("Reduce(function(a, b) a + b, 1:4)"),
            RVal::scalar_dbl(10.0)
        );
    }

    #[test]
    fn unlist_flattens_named() {
        let v = run("unlist(list(a = 1, b = 2))");
        assert_eq!(v.as_dbl_vec().unwrap(), vec![1.0, 2.0]);
        assert_eq!(v.names().unwrap(), &["a".to_string(), "b".to_string()]);
    }

    #[test]
    fn data_frame_columns() {
        let v = run("df <- data.frame(a = 1:4, b = letters[1:4])\nncol(df)");
        assert_eq!(v, RVal::scalar_int(2));
    }

    #[test]
    fn stopifnot_errors() {
        assert!(Interp::new().eval_program("stopifnot(1 == 2)").is_err());
        assert!(Interp::new().eval_program("stopifnot(1 == 1)").is_ok());
    }

    #[test]
    fn sort_and_unique() {
        assert_eq!(run("sort(c(3, 1, 2))"), RVal::dbl(vec![1.0, 2.0, 3.0]));
        assert_eq!(run("unique(c(1, 1, 2))"), RVal::dbl(vec![1.0, 2.0]));
    }

    #[test]
    fn sprintf_basic() {
        assert_eq!(run("sprintf(\"n=%d x=%.2f\", 3, 1.5)"), RVal::scalar_str("n=3 x=1.50"));
    }
}
