//! Builtin registry.
//!
//! Every callable that is not a user closure lives here, tagged with its
//! originating *namespace* ("package"). The namespace tag is what the
//! futurize transpiler uses for **function identification** (paper §3.2,
//! step 2): `lapply` resolves to `base::lapply`, `map` to `purrr::map`,
//! and transpiler lookup is keyed on `(namespace, name)`.
//!
//! Builtins come in two kinds:
//! - `Normal` — arguments are evaluated before the call (most functions);
//! - `Special` — receives the raw argument [`Expr`]s (NSE): `futurize()`,
//!   `quote()`, `suppressMessages()`, `tryCatch()`, `%do%`, `local()`, ...

use std::collections::HashMap;

use once_cell::sync::Lazy;

use super::ast::Arg;
use super::env::EnvRef;
use super::eval::{EvalResult, Interp, Signal};
use super::value::RVal;

pub mod control;
pub mod core;
pub mod io;
pub mod math;
pub mod stats_rng;
pub mod testhooks;

/// Evaluated arguments of a Normal builtin call.
#[derive(Clone, Debug)]
pub struct Args {
    pub items: Vec<(Option<String>, RVal)>,
}

/// Result of matching arguments against a parameter list.
pub struct Bound {
    pub vals: Vec<Option<RVal>>,
    /// Unmatched arguments, in order (the `...` of the call).
    pub rest: Vec<(Option<String>, RVal)>,
}

impl Args {
    pub fn new(items: Vec<(Option<String>, RVal)>) -> Self {
        Args { items }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// R-style argument matching: named arguments bind by exact name;
    /// unnamed arguments fill the remaining parameters left-to-right;
    /// everything else lands in `rest`.
    pub fn bind(&self, params: &[&str]) -> Bound {
        let mut vals: Vec<Option<RVal>> = vec![None; params.len()];
        let mut rest = Vec::new();
        let mut positional: Vec<RVal> = Vec::new();
        for (name, val) in &self.items {
            match name {
                Some(n) => match params.iter().position(|p| p == n) {
                    Some(idx) => vals[idx] = Some(val.clone()),
                    None => rest.push((Some(n.clone()), val.clone())),
                },
                None => positional.push(val.clone()),
            }
        }
        let mut pos = positional.into_iter();
        for (idx, _) in params.iter().enumerate() {
            if vals[idx].is_none() {
                if let Some(v) = pos.next() {
                    vals[idx] = Some(v);
                }
            }
        }
        for v in pos {
            rest.push((None, v));
        }
        Bound { vals, rest }
    }

    /// Named argument lookup (no positional fallback).
    pub fn named(&self, name: &str) -> Option<&RVal> {
        self.items
            .iter()
            .find(|(n, _)| n.as_deref() == Some(name))
            .map(|(_, v)| v)
    }

    /// All positional (unnamed) arguments, in order.
    pub fn positional(&self) -> Vec<&RVal> {
        self.items.iter().filter(|(n, _)| n.is_none()).map(|(_, v)| v).collect()
    }
}

impl Bound {
    pub fn req(&self, i: usize, what: &str) -> Result<RVal, Signal> {
        self.vals
            .get(i)
            .and_then(|v| v.clone())
            .ok_or_else(|| {
                Signal::error(format!("argument \"{what}\" is missing, with no default"))
            })
    }
    pub fn opt(&self, i: usize) -> Option<RVal> {
        self.vals.get(i).and_then(|v| v.clone())
    }
}

/// A builtin implementation. Boxed closures allow families of related
/// functions (purrr's 20+ map variants, furrr's mirrors) to be
/// mass-registered from parameterized templates.
pub enum BuiltinFn {
    Normal(Box<dyn Fn(&mut Interp, Args, &EnvRef) -> EvalResult + Send + Sync>),
    Special(Box<dyn Fn(&mut Interp, &[Arg], &EnvRef) -> EvalResult + Send + Sync>),
}

/// Dense registry slot of a builtin. `RVal::Builtin` carries this, so
/// call dispatch indexes a `Vec` instead of hashing a `"pkg::name"`
/// string per call.
pub type BuiltinId = u32;

/// A registered builtin.
pub struct BuiltinDef {
    pub name: &'static str,
    pub pkg: &'static str,
    /// This def's slot in [`Registry::defs`].
    pub id: BuiltinId,
    pub f: BuiltinFn,
}

impl BuiltinDef {
    pub fn key(&self) -> String {
        format!("{}::{}", self.pkg, self.name)
    }
}

/// The global registry: defs in registration order (indexed by
/// [`BuiltinId`]), a `"pkg::name"` key index, and an unqualified-name
/// index (first registration wins — base R registers first, mirroring
/// R's search path).
pub struct Registry {
    pub defs: Vec<BuiltinDef>,
    pub by_key: HashMap<String, BuiltinId>,
    pub by_name: HashMap<&'static str, BuiltinId>,
    /// Registration order of packages (for `futurize_supported_packages`).
    pub packages: Vec<&'static str>,
}

impl Registry {
    fn register(&mut self, mut def: BuiltinDef) {
        if !self.packages.contains(&def.pkg) {
            self.packages.push(def.pkg);
        }
        let id = self.defs.len() as BuiltinId;
        def.id = id;
        let key = def.key();
        self.by_name.entry(def.name).or_insert(id);
        let prev = self.by_key.insert(key.clone(), id);
        debug_assert!(prev.is_none(), "duplicate builtin {key}");
        self.defs.push(def);
    }
}

/// Registration helper used by every module that contributes builtins.
pub struct Reg<'a>(pub &'a mut Registry);

impl<'a> Reg<'a> {
    pub fn normal(
        &mut self,
        pkg: &'static str,
        name: &'static str,
        f: impl Fn(&mut Interp, Args, &EnvRef) -> EvalResult + Send + Sync + 'static,
    ) {
        self.0.register(BuiltinDef { name, pkg, id: 0, f: BuiltinFn::Normal(Box::new(f)) });
    }
    pub fn special(
        &mut self,
        pkg: &'static str,
        name: &'static str,
        f: impl Fn(&mut Interp, &[Arg], &EnvRef) -> EvalResult + Send + Sync + 'static,
    ) {
        self.0.register(BuiltinDef { name, pkg, id: 0, f: BuiltinFn::Special(Box::new(f)) });
    }
}

static REGISTRY: Lazy<Registry> = Lazy::new(|| {
    let mut reg = Registry {
        defs: Vec::new(),
        by_key: HashMap::new(),
        by_name: HashMap::new(),
        packages: Vec::new(),
    };
    {
        let mut r = Reg(&mut reg);
        // Order matters for unqualified-name resolution: base first.
        core::register(&mut r);
        math::register(&mut r);
        io::register(&mut r);
        control::register(&mut r);
        stats_rng::register(&mut r);
        testhooks::register(&mut r);
        // Upper layers (same crate, higher-level modules).
        crate::future_core::register_builtins(&mut r);
        crate::transpile::register_builtins(&mut r);
        crate::apis::register_builtins(&mut r);
        crate::domains::register_builtins(&mut r);
        crate::progress::register_builtins(&mut r);
        crate::runtime::register_builtins(&mut r);
    }
    reg
});

pub fn registry() -> &'static Registry {
    &REGISTRY
}

/// Resolve an unqualified name to its builtin (search-path order).
pub fn lookup_builtin(name: &str) -> Option<&'static BuiltinDef> {
    let id = *REGISTRY.by_name.get(name)?;
    REGISTRY.defs.get(id as usize)
}

/// Resolve `pkg::name`.
pub fn lookup_builtin_ns(pkg: &str, name: &str) -> Option<&'static BuiltinDef> {
    let id = *REGISTRY.by_key.get(&format!("{pkg}::{name}"))?;
    REGISTRY.defs.get(id as usize)
}

/// Resolve a registry key (`"pkg::name"`).
pub fn get_builtin(key: &str) -> Option<&'static BuiltinDef> {
    let id = *REGISTRY.by_key.get(key)?;
    REGISTRY.defs.get(id as usize)
}

/// Resolve a pre-assigned id to its def — the per-call dispatch path
/// (array index, no hashing).
pub fn builtin_by_id(id: BuiltinId) -> Option<&'static BuiltinDef> {
    REGISTRY.defs.get(id as usize)
}

/// The id of a registry key, for wire decode.
pub fn id_for_key(key: &str) -> Option<BuiltinId> {
    REGISTRY.by_key.get(key).copied()
}

/// The namespace a function name belongs to, if it is a builtin — used by
/// the transpiler's function-identification step.
pub fn namespace_of(name: &str) -> Option<&'static str> {
    lookup_builtin(name).map(|d| d.pkg)
}

/// All functions registered under a package (for
/// `futurize_supported_functions()` display and Table-1/2 coverage tests).
pub fn functions_in_package(pkg: &str) -> Vec<&'static str> {
    let mut out: Vec<&'static str> =
        REGISTRY.defs.iter().filter(|d| d.pkg == pkg).map(|d| d.name).collect();
    out.sort();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base_registers_before_others() {
        let d = lookup_builtin("lapply").expect("lapply registered");
        assert_eq!(d.pkg, "base");
    }

    #[test]
    fn ids_are_dense_and_consistent() {
        let reg = registry();
        for (k, d) in reg.defs.iter().enumerate() {
            assert_eq!(d.id as usize, k, "def {} has wrong id", d.key());
        }
        let d = lookup_builtin("sum").unwrap();
        assert!(std::ptr::eq(builtin_by_id(d.id).unwrap(), d));
        assert_eq!(id_for_key("base::sum"), Some(d.id));
    }

    #[test]
    fn namespaced_lookup() {
        assert!(lookup_builtin_ns("base", "lapply").is_some());
        assert!(lookup_builtin_ns("purrr", "map").is_some());
        assert!(lookup_builtin_ns("nosuch", "lapply").is_none());
    }

    #[test]
    fn args_bind_matches_r_semantics() {
        let args = Args::new(vec![
            (Some("n".into()), RVal::scalar_dbl(3.0)),
            (None, RVal::scalar_dbl(2.0)),
        ]);
        let b = args.bind(&["x", "n"]);
        assert_eq!(b.req(0, "x").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(b.req(1, "n").unwrap().as_f64().unwrap(), 3.0);
    }

    #[test]
    fn args_bind_collects_rest() {
        let args = Args::new(vec![
            (None, RVal::scalar_dbl(1.0)),
            (None, RVal::scalar_dbl(2.0)),
            (Some("extra".into()), RVal::scalar_bool(true)),
        ]);
        let b = args.bind(&["x"]);
        assert_eq!(b.rest.len(), 2);
    }
}
