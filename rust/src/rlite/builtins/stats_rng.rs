//! Random-number builtins, backed by the MRG32k3a stream in the
//! interpreter. Every call sets `rng_used`, which is how the futureverse
//! detects "RNG used without `seed = TRUE`" misuse (paper §5.2).

use super::{Args, Reg};
use crate::rlite::env::EnvRef;
use crate::rlite::eval::{EvalResult, Interp, Signal};
use crate::rlite::value::RVal;
use crate::rng::RngStream;

pub fn register(r: &mut Reg) {
    r.normal("base", "set.seed", set_seed_fn);
    r.normal("stats", "rnorm", rnorm_fn);
    r.normal("stats", "runif", runif_fn);
    r.normal("stats", "rexp", rexp_fn);
    r.normal("stats", "rbinom", rbinom_fn);
    r.normal("stats", "rpois", rpois_fn);
    r.normal("base", "sample", sample_fn);
    r.normal("base", "sample.int", sample_int_fn);
}

fn set_seed_fn(i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let seed = args.bind(&["seed"]).req(0, "seed")?.as_i64().map_err(Signal::error)?;
    i.rng = RngStream::from_seed(seed as u64);
    Ok(RVal::Null)
}

fn rnorm_fn(i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["n", "mean", "sd"]);
    let n = b.req(0, "n")?.as_usize().map_err(Signal::error)?;
    let mean = b.opt(1).map(|v| v.as_f64()).transpose().map_err(Signal::error)?.unwrap_or(0.0);
    let sd = b.opt(2).map(|v| v.as_f64()).transpose().map_err(Signal::error)?.unwrap_or(1.0);
    i.rng_used = true;
    let out: Vec<f64> = (0..n).map(|_| mean + sd * i.rng.next_normal()).collect();
    Ok(RVal::dbl(out))
}

fn runif_fn(i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["n", "min", "max"]);
    let n = b.req(0, "n")?.as_usize().map_err(Signal::error)?;
    let lo = b.opt(1).map(|v| v.as_f64()).transpose().map_err(Signal::error)?.unwrap_or(0.0);
    let hi = b.opt(2).map(|v| v.as_f64()).transpose().map_err(Signal::error)?.unwrap_or(1.0);
    i.rng_used = true;
    let out: Vec<f64> = (0..n).map(|_| lo + (hi - lo) * i.rng.next_f64()).collect();
    Ok(RVal::dbl(out))
}

fn rexp_fn(i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["n", "rate"]);
    let n = b.req(0, "n")?.as_usize().map_err(Signal::error)?;
    let rate = b.opt(1).map(|v| v.as_f64()).transpose().map_err(Signal::error)?.unwrap_or(1.0);
    i.rng_used = true;
    let out: Vec<f64> = (0..n).map(|_| -i.rng.next_f64().ln() / rate).collect();
    Ok(RVal::dbl(out))
}

fn rbinom_fn(i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["n", "size", "prob"]);
    let n = b.req(0, "n")?.as_usize().map_err(Signal::error)?;
    let size = b.req(1, "size")?.as_usize().map_err(Signal::error)?;
    let prob = b.req(2, "prob")?.as_f64().map_err(Signal::error)?;
    i.rng_used = true;
    let out: Vec<f64> = (0..n)
        .map(|_| (0..size).filter(|_| i.rng.next_f64() < prob).count() as f64)
        .collect();
    Ok(RVal::dbl(out))
}

fn rpois_fn(i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["n", "lambda"]);
    let n = b.req(0, "n")?.as_usize().map_err(Signal::error)?;
    let lambda = b.req(1, "lambda")?.as_f64().map_err(Signal::error)?;
    i.rng_used = true;
    // Knuth's algorithm (fine for the small lambdas in examples).
    let out: Vec<f64> = (0..n)
        .map(|_| {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= i.rng.next_f64();
                if p <= l {
                    break;
                }
                k += 1;
            }
            k as f64
        })
        .collect();
    Ok(RVal::dbl(out))
}

fn sample_fn(i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["x", "size", "replace"]);
    let x = b.req(0, "x")?;
    // sample(n) == sample(1:n) for scalar n > 1.
    let pool: Vec<RVal> = if x.len() == 1 && matches!(x, RVal::Dbl(_) | RVal::Int(_)) {
        let n = x.as_usize().map_err(Signal::error)?;
        (1..=n as i64).map(RVal::scalar_int).collect()
    } else {
        x.iter_elements()
    };
    let size = b
        .opt(1)
        .filter(|v| !v.is_null())
        .map(|v| v.as_usize())
        .transpose()
        .map_err(Signal::error)?
        .unwrap_or(pool.len());
    let replace =
        b.opt(2).map(|v| v.as_bool()).transpose().map_err(Signal::error)?.unwrap_or(false);
    i.rng_used = true;
    if pool.is_empty() {
        return Ok(RVal::Null);
    }
    let mut out: Vec<RVal> = Vec::with_capacity(size);
    if replace {
        for _ in 0..size {
            out.push(pool[i.rng.next_below(pool.len())].clone());
        }
    } else {
        if size > pool.len() {
            return Err(Signal::error("cannot take a sample larger than the population"));
        }
        // Fisher-Yates over indices.
        let mut idx: Vec<usize> = (0..pool.len()).collect();
        for k in 0..size {
            let j = k + i.rng.next_below(idx.len() - k);
            idx.swap(k, j);
            out.push(pool[idx[k]].clone());
        }
    }
    super::core::combine(out.into_iter().map(|v| (None, v)).collect())
}

fn sample_int_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    sample_fn(i, args, env)
}

#[cfg(test)]
mod tests {
    use crate::rlite::eval::Interp;
    use crate::rlite::value::RVal;

    fn run(src: &str) -> RVal {
        Interp::new().eval_program(src).unwrap_or_else(|e| panic!("{src}: {e:?}"))
    }

    #[test]
    fn set_seed_reproduces() {
        let a = run("set.seed(42)\nrnorm(5)");
        let b = run("set.seed(42)\nrnorm(5)");
        assert_eq!(a, b);
        let c = run("set.seed(43)\nrnorm(5)");
        assert_ne!(a, c);
    }

    #[test]
    fn runif_in_range() {
        let v = run("set.seed(1)\nrunif(100, 2, 3)").as_dbl_vec().unwrap();
        assert!(v.iter().all(|&x| (2.0..3.0).contains(&x)));
    }

    #[test]
    fn sample_without_replacement_is_permutation() {
        let mut v = run("set.seed(1)\nsample(10)").as_dbl_vec().unwrap();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(v, (1..=10).map(|x| x as f64).collect::<Vec<_>>());
    }

    #[test]
    fn sample_with_replacement_size() {
        let v = run("set.seed(1)\nsample(3, size = 50, replace = TRUE)").as_dbl_vec().unwrap();
        assert_eq!(v.len(), 50);
        assert!(v.iter().all(|&x| (1.0..=3.0).contains(&x)));
    }

    #[test]
    fn rng_used_flag_set() {
        let mut i = Interp::new();
        assert!(!i.rng_used);
        i.eval_program("rnorm(1)").unwrap();
        assert!(i.rng_used);
    }
}
