//! Output and condition builtins: `cat`, `print`, `message`, `warning`,
//! `stop`, suppressors, `tryCatch`, `withCallingHandlers`, timing.

use super::{Args, Reg};
use crate::rlite::ast::{Arg, Expr};
use crate::rlite::conditions::RCondition;
use crate::rlite::env::EnvRef;
use crate::rlite::eval::{EvalResult, HandlerFrame, Interp, Signal};
use crate::rlite::value::RVal;

pub fn register(r: &mut Reg) {
    r.normal("base", "cat", cat_fn);
    r.normal("base", "print", print_fn);
    r.normal("base", "str", str_fn);
    r.normal("base", "format", format_fn);
    r.normal("base", "message", message_fn);
    r.normal("base", "warning", warning_fn);
    r.normal("base", "stop", stop_fn);
    r.normal("base", "conditionMessage", condition_message_fn);
    r.normal("base", "conditionCall", condition_call_fn);
    r.normal("base", "signalCondition", signal_condition_fn);
    r.normal("base", "simpleCondition", simple_condition_fn);
    r.special("base", "suppressMessages", suppress_messages_fn);
    r.special("base", "suppressWarnings", suppress_warnings_fn);
    r.special("base", "tryCatch", try_catch_fn);
    r.special("base", "try", try_fn);
    r.special("base", "withCallingHandlers", with_calling_handlers_fn);
    r.special("base", "capture.output", capture_output_fn);
    r.special("base", "system.time", system_time_fn);
    r.normal("base", "Sys.sleep", sys_sleep_fn);
    r.normal("base", "Sys.time", sys_time_fn);
    r.normal("base", "Sys.getenv", sys_getenv_fn);
    r.normal("base", "proc.time", proc_time_fn);
}

fn render_for_cat(v: &RVal) -> Result<String, Signal> {
    match v {
        RVal::Null => Ok(String::new()),
        other => Ok(other.as_str_vec().map_err(Signal::error)?.join(" ")),
    }
}

fn cat_fn(i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let sep = args
        .named("sep")
        .map(|v| v.as_str())
        .transpose()
        .map_err(Signal::error)?
        .unwrap_or_else(|| " ".to_string());
    let parts: Vec<String> = args
        .items
        .iter()
        .filter(|(n, _)| n.as_deref() != Some("sep"))
        .map(|(_, v)| render_for_cat(v))
        .collect::<Result<_, _>>()?;
    i.write_out(&parts.join(&sep));
    Ok(RVal::Null)
}

fn print_fn(i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let x = args.bind(&["x"]).req(0, "x")?;
    let text = format!("{x}\n");
    i.write_out(&text);
    Ok(x)
}

fn str_fn(i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let x = args.bind(&["object"]).req(0, "object")?;
    let text = format!("{} [len {}]\n", x.class(), x.len());
    i.write_out(&text);
    Ok(RVal::Null)
}

fn format_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let x = args.bind(&["x"]).req(0, "x")?;
    Ok(RVal::chr(x.as_str_vec().map_err(Signal::error)?))
}

fn msg_text(args: &Args) -> Result<String, Signal> {
    let parts: Vec<String> = args
        .items
        .iter()
        .filter(|(n, _)| n.is_none())
        .map(|(_, v)| match v {
            RVal::Cond(c) => Ok(c.message.clone()),
            other => other.as_str_vec().map_err(Signal::error).map(|v| v.join("")),
        })
        .collect::<Result<_, _>>()?;
    Ok(parts.join(""))
}

fn message_fn(i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let text = msg_text(&args)?;
    i.signal_condition(RCondition::message_cond(format!("{text}\n")))?;
    Ok(RVal::Null)
}

fn warning_fn(i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let text = msg_text(&args)?;
    i.signal_condition(RCondition::warning_cond(text))?;
    Ok(RVal::Null)
}

fn stop_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    // stop(cond) re-raises a condition object as-is (error preservation —
    // the behaviour the paper contrasts against mclapply/parLapply).
    if let Some((_, RVal::Cond(c))) = args.items.first() {
        return Err(Signal::Error((**c).clone()));
    }
    Err(Signal::Error(RCondition::error_cond(msg_text(&args)?)))
}

fn condition_message_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    match args.bind(&["c"]).req(0, "c")? {
        RVal::Cond(c) => Ok(RVal::scalar_str(c.message.clone())),
        other => Err(Signal::error(format!("not a condition: {}", other.class()))),
    }
}

fn condition_call_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    match args.bind(&["c"]).req(0, "c")? {
        RVal::Cond(c) => Ok(match &c.call {
            Some(call) => RVal::scalar_str(call.clone()),
            None => RVal::Null,
        }),
        other => Err(Signal::error(format!("not a condition: {}", other.class()))),
    }
}

fn simple_condition_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["message", "class"]);
    let msg = b.req(0, "message")?.as_str().map_err(Signal::error)?;
    let class = b
        .opt(1)
        .map(|v| v.as_str())
        .transpose()
        .map_err(Signal::error)?
        .unwrap_or_else(|| "simpleCondition".into());
    Ok(RVal::Cond(Box::new(RCondition::custom(&class, msg, None))))
}

fn signal_condition_fn(i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    match args.bind(&["cond"]).req(0, "cond")? {
        RVal::Cond(c) => {
            i.signal_condition(*c)?;
            Ok(RVal::Null)
        }
        other => Err(Signal::error(format!("not a condition: {}", other.class()))),
    }
}

// ---- suppressors / handlers ---------------------------------------------------

fn suppress_impl(i: &mut Interp, args: &[Arg], env: &EnvRef, classes: Vec<String>) -> EvalResult {
    let expr = args
        .first()
        .ok_or_else(|| Signal::error("nothing to evaluate"))?;
    i.handlers.push(HandlerFrame::Suppress { classes });
    let r = i.eval(&expr.value, env);
    i.handlers.pop();
    r
}

fn suppress_messages_fn(i: &mut Interp, args: &[Arg], env: &EnvRef) -> EvalResult {
    suppress_impl(i, args, env, vec!["message".into()])
}

fn suppress_warnings_fn(i: &mut Interp, args: &[Arg], env: &EnvRef) -> EvalResult {
    suppress_impl(i, args, env, vec!["warning".into()])
}

/// `tryCatch(expr, error = f, warning = f, ..., finally = expr)`.
/// Handlers are *exiting*: a matching condition unwinds evaluation of
/// `expr` and the handler's value becomes the result.
fn try_catch_fn(i: &mut Interp, args: &[Arg], env: &EnvRef) -> EvalResult {
    let mut expr: Option<&Expr> = None;
    let mut handlers: Vec<(String, RVal)> = Vec::new();
    let mut finally: Option<&Expr> = None;
    for a in args {
        match a.name.as_deref() {
            None => {
                if expr.is_none() {
                    expr = Some(&a.value)
                }
            }
            Some("finally") => finally = Some(&a.value),
            Some(class) => {
                let f = i.eval(&a.value, env)?;
                handlers.push((class.to_string(), f));
            }
        }
    }
    let expr = expr.ok_or_else(|| Signal::error("tryCatch: missing expression"))?;
    let id = i.fresh_frame_id();
    let classes: Vec<String> = handlers
        .iter()
        .map(|(c, _)| c.clone())
        .filter(|c| c != "error") // errors arrive via Signal::Error, not signal_condition
        .collect();
    let pushed = if classes.is_empty() {
        false
    } else {
        i.handlers.push(HandlerFrame::Exiting { classes, id });
        true
    };
    let result = i.eval(expr, env);
    if pushed {
        i.handlers.pop();
    }
    let out = match result {
        Ok(v) => Ok(v),
        Err(Signal::Unwind { cond, id: uid }) if uid == id => {
            // Find the most specific matching handler.
            let handler = handlers
                .iter()
                .find(|(c, _)| cond.inherits(c))
                .map(|(_, f)| f.clone());
            match handler {
                Some(f) => i.call_function(&f, vec![(None, RVal::Cond(Box::new(cond)))], env),
                None => Err(Signal::Error(cond)),
            }
        }
        Err(Signal::Error(cond)) => {
            let handler = handlers
                .iter()
                .find(|(c, _)| cond.inherits(c) || c == "error" || c == "condition")
                .map(|(_, f)| f.clone());
            match handler {
                Some(f) => i.call_function(&f, vec![(None, RVal::Cond(Box::new(cond)))], env),
                None => Err(Signal::Error(cond)),
            }
        }
        Err(other) => Err(other),
    };
    if let Some(fin) = finally {
        i.eval(fin, env)?;
    }
    out
}

/// `try(expr)`: evaluate; on error return the condition (class
/// "try-error"-ish) instead of propagating.
fn try_fn(i: &mut Interp, args: &[Arg], env: &EnvRef) -> EvalResult {
    let expr = args.first().ok_or_else(|| Signal::error("try: missing expression"))?;
    match i.eval(&expr.value, env) {
        Ok(v) => Ok(v),
        Err(Signal::Error(mut cond)) => {
            cond.classes.insert(0, "try-error".into());
            Ok(RVal::Cond(Box::new(cond)))
        }
        Err(other) => Err(other),
    }
}

fn with_calling_handlers_fn(i: &mut Interp, args: &[Arg], env: &EnvRef) -> EvalResult {
    let mut expr: Option<&Expr> = None;
    let mut pushed = 0usize;
    for a in args {
        match a.name.as_deref() {
            None => {
                if expr.is_none() {
                    expr = Some(&a.value)
                }
            }
            Some(class) => {
                let f = i.eval(&a.value, env)?;
                i.handlers.push(HandlerFrame::Calling { class: class.to_string(), func: f });
                pushed += 1;
            }
        }
    }
    let expr = expr.ok_or_else(|| Signal::error("withCallingHandlers: missing expression"))?;
    let r = i.eval(expr, env);
    for _ in 0..pushed {
        i.handlers.pop();
    }
    r
}

fn capture_output_fn(i: &mut Interp, args: &[Arg], env: &EnvRef) -> EvalResult {
    let expr = args.first().ok_or_else(|| Signal::error("capture.output: missing expr"))?;
    let (r, text) = i.capture_stdout(|i| i.eval(&expr.value, env));
    r?;
    let lines: Vec<String> = text.lines().map(|s| s.to_string()).collect();
    Ok(RVal::chr(lines))
}

fn system_time_fn(i: &mut Interp, args: &[Arg], env: &EnvRef) -> EvalResult {
    let expr = args.first().ok_or_else(|| Signal::error("system.time: missing expr"))?;
    let t0 = std::time::Instant::now();
    i.eval(&expr.value, env)?;
    let dt = t0.elapsed().as_secs_f64();
    Ok(RVal::Dbl(crate::rlite::value::RVec::named(
        vec![dt, 0.0, dt],
        vec!["user.self".into(), "sys.self".into(), "elapsed".into()],
    )))
}

fn sys_sleep_fn(i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let secs = args.bind(&["time"]).req(0, "time")?.as_f64().map_err(Signal::error)?;
    let scaled = secs * i.config.time_scale;
    if scaled > 0.0 {
        std::thread::sleep(std::time::Duration::from_secs_f64(scaled));
    }
    Ok(RVal::Null)
}

fn sys_time_fn(_i: &mut Interp, _args: Args, _env: &EnvRef) -> EvalResult {
    let now = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap_or_default()
        .as_secs_f64();
    Ok(RVal::scalar_dbl(now))
}

fn sys_getenv_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let name = args.bind(&["x"]).req(0, "x")?.as_str().map_err(Signal::error)?;
    Ok(RVal::scalar_str(std::env::var(&name).unwrap_or_default()))
}

fn proc_time_fn(_i: &mut Interp, _args: Args, _env: &EnvRef) -> EvalResult {
    let now = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap_or_default()
        .as_secs_f64();
    Ok(RVal::Dbl(crate::rlite::value::RVec::named(
        vec![now, 0.0, now],
        vec!["user.self".into(), "sys.self".into(), "elapsed".into()],
    )))
}

#[cfg(test)]
mod tests {
    use crate::rlite::eval::Interp;
    use crate::rlite::value::RVal;

    fn run(src: &str) -> RVal {
        Interp::new().eval_program(src).unwrap_or_else(|e| panic!("{src}: {e:?}"))
    }

    fn run_captured(src: &str) -> (RVal, String) {
        let mut i = Interp::new();
        let (r, text) = i.capture_stdout(|i| i.eval_program(src));
        (r.unwrap(), text)
    }

    #[test]
    fn cat_writes_stdout() {
        let (_, out) = run_captured("cat(\"x =\", 1, \"\\n\")");
        assert_eq!(out, "x = 1 \n");
    }

    #[test]
    fn suppress_messages_muffles() {
        let (_, out) = run_captured("suppressMessages(message(\"loud\"))");
        assert_eq!(out, "");
        let (_, out) = run_captured("message(\"loud\")");
        assert_eq!(out, "loud\n");
    }

    #[test]
    fn suppress_warnings_muffles_only_warnings() {
        let (_, out) = run_captured("suppressWarnings({ warning(\"w\")\nmessage(\"m\") })");
        assert_eq!(out, "m\n");
    }

    #[test]
    fn try_catch_error_handler() {
        let v = run("tryCatch(stop(\"boom\"), error = function(e) conditionMessage(e))");
        assert_eq!(v, RVal::scalar_str("boom"));
    }

    #[test]
    fn try_catch_warning_is_exiting() {
        let v = run("tryCatch({ warning(\"w\")\n\"not reached\" }, warning = function(w) \"caught\")");
        assert_eq!(v, RVal::scalar_str("caught"));
    }

    #[test]
    fn try_catch_finally_runs() {
        let v = run("x <- 0\ntryCatch(stop(\"e\"), error = function(e) 1, finally = x <- 99)\nx");
        assert_eq!(v, RVal::scalar_dbl(99.0));
    }

    #[test]
    fn try_returns_condition() {
        let v = run("r <- try(stop(\"oops\"))\ninherits(r, \"try-error\")");
        assert_eq!(v, RVal::scalar_bool(true));
    }

    #[test]
    fn stop_preserves_condition_object() {
        // Error objects survive re-raising (the paper's §1 critique of
        // mclapply, which loses the original condition).
        let v = run(
            "e <- tryCatch(stop(\"original\"), error = function(e) e)\n\
             r <- tryCatch(stop(e), error = function(e2) conditionMessage(e2))\nr",
        );
        assert_eq!(v, RVal::scalar_str("original"));
    }

    #[test]
    fn with_calling_handlers_continues() {
        let v = run(
            "hits <- 0\nr <- withCallingHandlers({ message(\"a\")\nmessage(\"b\")\n42 },\n\
             message = function(m) hits <<- hits + 1)\nc(r, hits)",
        );
        assert_eq!(v, RVal::dbl(vec![42.0, 2.0]));
    }

    #[test]
    fn capture_output_returns_lines() {
        let v = run("capture.output({ cat(\"l1\\n\")\ncat(\"l2\\n\") })");
        assert_eq!(v, RVal::chr(vec!["l1".into(), "l2".into()]));
    }

    #[test]
    fn warning_then_value() {
        let (v, out) = run_captured("{ warning(\"careful\")\n7 }");
        assert_eq!(v, RVal::scalar_dbl(7.0));
        assert!(out.contains("careful"));
    }
}
