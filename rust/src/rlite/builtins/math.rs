//! Vectorized arithmetic, comparison, and numeric summaries.

use super::{Args, Reg};
use crate::rlite::env::EnvRef;
use crate::rlite::eval::{EvalResult, Interp, Signal};
use crate::rlite::value::{RVal, RVec};

pub fn register(r: &mut Reg) {
    r.normal("base", "+", add_fn);
    r.normal("base", "-", sub_fn);
    r.normal("base", "*", mul_fn);
    r.normal("base", "/", div_fn);
    r.normal("base", "^", pow_fn);
    r.normal("base", "%%", mod_fn);
    r.normal("base", "%/%", intdiv_fn);
    r.normal("base", "==", eq_fn);
    r.normal("base", "!=", neq_fn);
    r.normal("base", "<", lt_fn);
    r.normal("base", ">", gt_fn);
    r.normal("base", "<=", le_fn);
    r.normal("base", ">=", ge_fn);
    r.normal("base", "&", and_fn);
    r.normal("base", "&&", and2_fn);
    r.normal("base", "|", or_fn);
    r.normal("base", "||", or2_fn);
    r.normal("base", "!", not_fn);
    r.normal("base", ":", range_fn);
    r.normal("base", "%in%", in_fn);
    r.normal("base", "sqrt", sqrt_fn);
    r.normal("base", "exp", exp_fn);
    r.normal("base", "log", log_fn);
    r.normal("base", "log2", log2_fn);
    r.normal("base", "log10", log10_fn);
    r.normal("base", "abs", abs_fn);
    r.normal("base", "floor", floor_fn);
    r.normal("base", "ceiling", ceiling_fn);
    r.normal("base", "round", round_fn);
    r.normal("base", "sin", sin_fn);
    r.normal("base", "cos", cos_fn);
    r.normal("base", "sum", sum_fn);
    r.normal("base", "prod", prod_fn);
    r.normal("base", "mean", mean_fn);
    r.normal("base", "cumsum", cumsum_fn);
    r.normal("stats", "median", median_fn);
    r.normal("stats", "var", var_fn);
    r.normal("stats", "sd", sd_fn);
    r.normal("stats", "quantile", quantile_fn);
    r.normal("stats", "weighted.mean", weighted_mean_fn);
    r.normal("stats", "cor", cor_fn);
    r.normal("base", "range", range_summary_fn);
    r.normal("base", "pmin", pmin_fn);
    r.normal("base", "pmax", pmax_fn);
}

/// Borrowed double view of an operand: zero-copy for `Dbl` values (the
/// hot case under COW), a scratch coercion for everything else.
fn dbl_view<'a>(v: &'a RVal, scratch: &'a mut Vec<f64>) -> Result<&'a [f64], Signal> {
    match v.as_dbl_slice() {
        Some(s) => Ok(s),
        None => {
            *scratch = v.as_dbl_vec().map_err(Signal::error)?;
            Ok(scratch)
        }
    }
}

/// Elementwise binary op with R recycling and name preservation.
fn binop(a: &RVal, b: &RVal, f: impl Fn(f64, f64) -> f64) -> EvalResult {
    // Scalar-scalar fast path: the dominant shape inside map bodies
    // (`x * 2 + 1`) — no coercion buffers, no recycling arithmetic.
    if let (RVal::Dbl(x), RVal::Dbl(y)) = (a, b) {
        if x.len() == 1 && y.len() == 1 && x.names.is_none() && y.names.is_none() {
            return Ok(RVal::scalar_dbl(f(x.vals[0], y.vals[0])));
        }
    }
    let (mut sa, mut sb) = (Vec::new(), Vec::new());
    let av = dbl_view(a, &mut sa)?;
    let bv = dbl_view(b, &mut sb)?;
    if av.is_empty() || bv.is_empty() {
        return Ok(RVal::dbl(vec![]));
    }
    let n = av.len().max(bv.len());
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        out.push(f(av[i % av.len()], bv[i % bv.len()]));
    }
    let names = if av.len() >= bv.len() {
        a.names().map(|x| x.to_vec())
    } else {
        b.names().map(|x| x.to_vec())
    };
    Ok(RVal::Dbl(RVec::with_names(out, names)))
}

fn cmpop(a: &RVal, b: &RVal, f: impl Fn(f64, f64) -> bool) -> EvalResult {
    if let (RVal::Dbl(x), RVal::Dbl(y)) = (a, b) {
        if x.len() == 1 && y.len() == 1 {
            return Ok(RVal::scalar_bool(f(x.vals[0], y.vals[0])));
        }
    }
    let (mut sa, mut sb) = (Vec::new(), Vec::new());
    let av = dbl_view(a, &mut sa)?;
    let bv = dbl_view(b, &mut sb)?;
    if av.is_empty() || bv.is_empty() {
        return Ok(RVal::lgl(vec![]));
    }
    let n = av.len().max(bv.len());
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        out.push(f(av[i % av.len()], bv[i % bv.len()]));
    }
    Ok(RVal::lgl(out))
}

macro_rules! bin {
    ($name:ident, $f:expr) => {
        fn $name(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
            let b = args.bind(&["e1", "e2"]);
            binop(&b.req(0, "e1")?, &b.req(1, "e2")?, $f)
        }
    };
}
macro_rules! cmp {
    ($name:ident, $f:expr) => {
        fn $name(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
            let b = args.bind(&["e1", "e2"]);
            cmpop(&b.req(0, "e1")?, &b.req(1, "e2")?, $f)
        }
    };
}

bin!(mul_fn, |a, b| a * b);
bin!(div_fn, |a, b| a / b);
bin!(pow_fn, |a, b| a.powf(b));
bin!(mod_fn, |a, b| a.rem_euclid(b));
bin!(intdiv_fn, |a, b| (a / b).floor());
cmp!(lt_fn, |a, b| a < b);
cmp!(gt_fn, |a, b| a > b);
cmp!(le_fn, |a, b| a <= b);
cmp!(ge_fn, |a, b| a >= b);

fn add_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["e1", "e2"]);
    let e1 = b.req(0, "e1")?;
    match b.opt(1) {
        Some(e2) => binop(&e1, &e2, |a, b| a + b),
        None => Ok(e1), // unary +
    }
}

fn sub_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["e1", "e2"]);
    let e1 = b.req(0, "e1")?;
    match b.opt(1) {
        Some(e2) => binop(&e1, &e2, |a, b| a - b),
        None => binop(&RVal::scalar_dbl(0.0), &e1, |a, b| a - b), // unary -
    }
}

fn eq_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["e1", "e2"]);
    let (x, y) = (b.req(0, "e1")?, b.req(1, "e2")?);
    // String comparison if either side is character.
    if matches!(x, RVal::Chr(_)) || matches!(y, RVal::Chr(_)) {
        let xs = x.as_str_vec().map_err(Signal::error)?;
        let ys = y.as_str_vec().map_err(Signal::error)?;
        let n = xs.len().max(ys.len());
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            out.push(xs[i % xs.len()] == ys[i % ys.len()]);
        }
        return Ok(RVal::lgl(out));
    }
    cmpop(&x, &y, |a, b| a == b)
}

fn neq_fn(i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    match eq_fn(i, args, env)? {
        RVal::Lgl(v) => Ok(RVal::lgl(v.vals.iter().map(|&b| !b).collect())),
        other => Ok(other),
    }
}

fn and_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["e1", "e2"]);
    cmpop(&b.req(0, "e1")?, &b.req(1, "e2")?, |a, b| a != 0.0 && b != 0.0)
}

fn or_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["e1", "e2"]);
    cmpop(&b.req(0, "e1")?, &b.req(1, "e2")?, |a, b| a != 0.0 || b != 0.0)
}

fn and2_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["e1", "e2"]);
    let x = b.req(0, "e1")?.as_bool().map_err(Signal::error)?;
    let y = b.req(1, "e2")?.as_bool().map_err(Signal::error)?;
    Ok(RVal::scalar_bool(x && y))
}

fn or2_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["e1", "e2"]);
    let x = b.req(0, "e1")?.as_bool().map_err(Signal::error)?;
    let y = b.req(1, "e2")?.as_bool().map_err(Signal::error)?;
    Ok(RVal::scalar_bool(x || y))
}

fn not_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let x = args.bind(&["x"]).req(0, "x")?;
    let d = x.as_dbl_vec().map_err(Signal::error)?;
    Ok(RVal::lgl(d.into_iter().map(|v| v == 0.0).collect()))
}

fn range_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["from", "to"]);
    let from = b.req(0, "from")?.as_f64().map_err(Signal::error)?;
    let to = b.req(1, "to")?.as_f64().map_err(Signal::error)?;
    let mut out = Vec::new();
    if from <= to {
        let mut x = from;
        while x <= to + 1e-9 {
            out.push(x as i64);
            x += 1.0;
        }
    } else {
        let mut x = from;
        while x >= to - 1e-9 {
            out.push(x as i64);
            x -= 1.0;
        }
    }
    Ok(RVal::int(out))
}

fn in_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["x", "table"]);
    let x = b.req(0, "x")?.as_str_vec().map_err(Signal::error)?;
    let table = b.req(1, "table")?.as_str_vec().map_err(Signal::error)?;
    Ok(RVal::lgl(x.iter().map(|e| table.contains(e)).collect()))
}

macro_rules! unary {
    ($name:ident, $f:expr) => {
        fn $name(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
            let x = args.bind(&["x"]).req(0, "x")?;
            let d = x.as_dbl_vec().map_err(Signal::error)?;
            let names = x.names().map(|n| n.to_vec());
            Ok(RVal::Dbl(RVec::with_names(d.into_iter().map($f).collect(), names)))
        }
    };
}

unary!(sqrt_fn, |x: f64| x.sqrt());
unary!(exp_fn, |x: f64| x.exp());
unary!(log2_fn, |x: f64| x.log2());
unary!(log10_fn, |x: f64| x.log10());
unary!(abs_fn, |x: f64| x.abs());
unary!(floor_fn, |x: f64| x.floor());
unary!(ceiling_fn, |x: f64| x.ceil());
unary!(sin_fn, |x: f64| x.sin());
unary!(cos_fn, |x: f64| x.cos());

fn log_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["x", "base"]);
    let x = b.req(0, "x")?.as_dbl_vec().map_err(Signal::error)?;
    match b.opt(1) {
        Some(base) => {
            let base = base.as_f64().map_err(Signal::error)?;
            Ok(RVal::dbl(x.into_iter().map(|v| v.log(base)).collect()))
        }
        None => Ok(RVal::dbl(x.into_iter().map(|v| v.ln()).collect())),
    }
}

fn round_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["x", "digits"]);
    let x = b.req(0, "x")?.as_dbl_vec().map_err(Signal::error)?;
    let digits =
        b.opt(1).map(|v| v.as_i64()).transpose().map_err(Signal::error)?.unwrap_or(0);
    let scale = 10f64.powi(digits as i32);
    Ok(RVal::dbl(x.into_iter().map(|v| (v * scale).round() / scale).collect()))
}

fn sum_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let mut s = 0.0;
    let mut scratch = Vec::new();
    for (_, v) in &args.items {
        for x in dbl_view(v, &mut scratch)? {
            s += x;
        }
    }
    Ok(RVal::scalar_dbl(s))
}

fn prod_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let mut p = 1.0;
    for (_, v) in &args.items {
        for x in v.as_dbl_vec().map_err(Signal::error)? {
            p *= x;
        }
    }
    Ok(RVal::scalar_dbl(p))
}

fn mean_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let x = args.bind(&["x"]).req(0, "x")?.as_dbl_vec().map_err(Signal::error)?;
    if x.is_empty() {
        return Ok(RVal::scalar_dbl(f64::NAN));
    }
    Ok(RVal::scalar_dbl(x.iter().sum::<f64>() / x.len() as f64))
}

fn cumsum_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let x = args.bind(&["x"]).req(0, "x")?.as_dbl_vec().map_err(Signal::error)?;
    let mut s = 0.0;
    Ok(RVal::dbl(
        x.into_iter()
            .map(|v| {
                s += v;
                s
            })
            .collect(),
    ))
}

fn median_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let mut x = args.bind(&["x"]).req(0, "x")?.as_dbl_vec().map_err(Signal::error)?;
    if x.is_empty() {
        return Ok(RVal::scalar_dbl(f64::NAN));
    }
    x.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let n = x.len();
    let m = if n % 2 == 1 { x[n / 2] } else { (x[n / 2 - 1] + x[n / 2]) / 2.0 };
    Ok(RVal::scalar_dbl(m))
}

fn var_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let x = args.bind(&["x"]).req(0, "x")?.as_dbl_vec().map_err(Signal::error)?;
    Ok(RVal::scalar_dbl(variance(&x)))
}

fn sd_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let x = args.bind(&["x"]).req(0, "x")?.as_dbl_vec().map_err(Signal::error)?;
    Ok(RVal::scalar_dbl(variance(&x).sqrt()))
}

pub fn variance(x: &[f64]) -> f64 {
    if x.len() < 2 {
        return f64::NAN;
    }
    let m = x.iter().sum::<f64>() / x.len() as f64;
    x.iter().map(|v| (v - m).powi(2)).sum::<f64>() / (x.len() as f64 - 1.0)
}

fn quantile_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["x", "probs"]);
    let mut x = b.req(0, "x")?.as_dbl_vec().map_err(Signal::error)?;
    let probs = b
        .opt(1)
        .map(|v| v.as_dbl_vec())
        .transpose()
        .map_err(Signal::error)?
        .unwrap_or_else(|| vec![0.0, 0.25, 0.5, 0.75, 1.0]);
    x.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    if x.is_empty() {
        return Err(Signal::error("quantile of empty vector"));
    }
    // Type-7 quantiles (R default).
    let q: Vec<f64> = probs
        .iter()
        .map(|&p| {
            let h = (x.len() as f64 - 1.0) * p;
            let lo = h.floor() as usize;
            let hi = h.ceil() as usize;
            x[lo] + (h - lo as f64) * (x[hi.min(x.len() - 1)] - x[lo])
        })
        .collect();
    Ok(RVal::dbl(q))
}

fn weighted_mean_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["x", "w"]);
    let x = b.req(0, "x")?.as_dbl_vec().map_err(Signal::error)?;
    let w = b.req(1, "w")?.as_dbl_vec().map_err(Signal::error)?;
    if x.len() != w.len() {
        return Err(Signal::error("'x' and 'w' must have the same length"));
    }
    let sw: f64 = w.iter().sum();
    let s: f64 = x.iter().zip(&w).map(|(a, b)| a * b).sum();
    Ok(RVal::scalar_dbl(s / sw))
}

fn cor_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["x", "y"]);
    let x = b.req(0, "x")?.as_dbl_vec().map_err(Signal::error)?;
    let y = b.req(1, "y")?.as_dbl_vec().map_err(Signal::error)?;
    if x.len() != y.len() || x.len() < 2 {
        return Err(Signal::error("incompatible dimensions in cor()"));
    }
    let mx = x.iter().sum::<f64>() / x.len() as f64;
    let my = y.iter().sum::<f64>() / y.len() as f64;
    let cov: f64 = x.iter().zip(&y).map(|(a, b)| (a - mx) * (b - my)).sum();
    let vx: f64 = x.iter().map(|a| (a - mx).powi(2)).sum();
    let vy: f64 = y.iter().map(|b| (b - my).powi(2)).sum();
    Ok(RVal::scalar_dbl(cov / (vx.sqrt() * vy.sqrt())))
}

fn range_summary_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for (_, v) in &args.items {
        for x in v.as_dbl_vec().map_err(Signal::error)? {
            lo = lo.min(x);
            hi = hi.max(x);
        }
    }
    Ok(RVal::dbl(vec![lo, hi]))
}

fn pmin_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["e1", "e2"]);
    binop(&b.req(0, "e1")?, &b.req(1, "e2")?, f64::min)
}

fn pmax_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["e1", "e2"]);
    binop(&b.req(0, "e1")?, &b.req(1, "e2")?, f64::max)
}

#[cfg(test)]
mod tests {
    use crate::rlite::eval::Interp;
    use crate::rlite::value::RVal;

    fn run(src: &str) -> RVal {
        Interp::new().eval_program(src).unwrap_or_else(|e| panic!("{src}: {e:?}"))
    }

    #[test]
    fn recycling() {
        assert_eq!(run("1:4 + 1"), RVal::dbl(vec![2.0, 3.0, 4.0, 5.0]));
        assert_eq!(run("c(1, 2, 3, 4) * c(1, 2)"), RVal::dbl(vec![1.0, 4.0, 3.0, 8.0]));
    }

    #[test]
    fn summaries() {
        assert_eq!(run("mean(1:10)"), RVal::scalar_dbl(5.5));
        assert_eq!(run("median(c(1, 9, 5))"), RVal::scalar_dbl(5.0));
        assert_eq!(run("sd(c(2, 4, 4, 4, 5, 5, 7, 9))").as_f64().unwrap().round(), 2.0);
    }

    #[test]
    fn descending_range() {
        assert_eq!(run("3:1"), RVal::int(vec![3, 2, 1]));
    }

    #[test]
    fn string_equality() {
        assert_eq!(run("\"a\" == \"a\""), RVal::scalar_bool(true));
        assert_eq!(run("\"a\" != \"b\""), RVal::scalar_bool(true));
    }

    #[test]
    fn in_operator() {
        assert_eq!(run("2 %in% c(1, 2, 3)"), RVal::lgl(vec![true]));
    }

    #[test]
    fn weighted_mean() {
        assert_eq!(run("weighted.mean(c(1, 3), c(1, 3))"), RVal::scalar_dbl(2.5));
    }

    #[test]
    fn quantile_type7() {
        let v = run("quantile(1:5, probs = c(0, 0.5, 1))");
        assert_eq!(v.as_dbl_vec().unwrap(), vec![1.0, 3.0, 5.0]);
    }
}
