//! Control-flow and metaprogramming builtins.

use super::{Args, Reg};
use crate::rlite::ast::Arg;
use crate::rlite::env::{self, Env, EnvRef};
use crate::rlite::eval::{EvalResult, Interp, Signal};
use crate::rlite::value::RVal;

pub fn register(r: &mut Reg) {
    r.normal("base", "return", return_fn);
    r.special("base", "local", local_fn);
    r.special("base", "quote", quote_fn);
    r.special("base", "substitute", quote_fn);
    r.special("base", "switch", switch_fn);
    r.normal("base", "ifelse", ifelse_fn);
    r.special("base", "library", library_fn);
    r.special("base", "require", library_fn);
    r.normal("base", "requireNamespace", require_namespace_fn);
    r.normal("base", "suppressPackageStartupMessages", super::core::c_fn);
    r.normal("base", "match.fun", match_fun_fn);
    r.normal("base", "force", force_fn);
    r.normal("base", "Negate", negate_fn);
    r.normal("base", "deparse", deparse_fn);
}

fn return_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let v = args.bind(&["value"]).opt(0).unwrap_or(RVal::Null);
    Err(Signal::Return(v))
}

/// `local({ ... })`: evaluate in a fresh child environment. The futurize
/// transpiler also knows how to *unwrap* `local()` (paper §3.3).
fn local_fn(i: &mut Interp, args: &[Arg], env: &EnvRef) -> EvalResult {
    let expr = args.first().ok_or_else(|| Signal::error("local: missing expression"))?;
    let child = Env::child_of(env);
    i.eval(&expr.value, &child)
}

/// `quote(expr)`: return the expression as a deparsed string (rlite has no
/// first-class language objects; the transpiler works on [`Expr`]s
/// directly, so this is only for display purposes).
fn quote_fn(_i: &mut Interp, args: &[Arg], _env: &EnvRef) -> EvalResult {
    let expr = args.first().ok_or_else(|| Signal::error("quote: missing expression"))?;
    Ok(RVal::scalar_str(crate::rlite::deparse::deparse(&expr.value)))
}

fn switch_fn(i: &mut Interp, args: &[Arg], env: &EnvRef) -> EvalResult {
    let sel = args.first().ok_or_else(|| Signal::error("switch: missing selector"))?;
    let key = i.eval(&sel.value, env)?.as_str().map_err(Signal::error)?;
    let mut default: Option<&Arg> = None;
    for a in &args[1..] {
        match &a.name {
            Some(n) if *n == key => return i.eval(&a.value, env),
            None => default = Some(a),
            _ => {}
        }
    }
    match default {
        Some(a) => i.eval(&a.value, env),
        None => Ok(RVal::Null),
    }
}

fn ifelse_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let b = args.bind(&["test", "yes", "no"]);
    let test = b.req(0, "test")?;
    let yes = b.req(1, "yes")?.as_dbl_vec().map_err(Signal::error)?;
    let no = b.req(2, "no")?.as_dbl_vec().map_err(Signal::error)?;
    let t = test.as_dbl_vec().map_err(Signal::error)?;
    let out: Vec<f64> = t
        .iter()
        .enumerate()
        .map(|(i, &c)| if c != 0.0 { yes[i % yes.len()] } else { no[i % no.len()] })
        .collect();
    Ok(RVal::dbl(out))
}

/// `library(pkg)` / `require(pkg)` — special form (the package name is a
/// bare symbol, as in R); validated no-op: the "package" must exist in
/// the builtin registry (all supported packages ship in-binary).
fn library_fn(_i: &mut Interp, args: &[Arg], _env: &EnvRef) -> EvalResult {
    let pkg = match args.first().map(|a| &a.value) {
        Some(crate::rlite::ast::Expr::Sym(s)) => s.to_string(),
        Some(crate::rlite::ast::Expr::Str(s)) => s.clone(),
        _ => return Err(Signal::error("library: missing package")),
    };
    let known = super::registry().packages.contains(&pkg.as_str())
        // Packages that are pure "future backends" in the paper have no
        // exported map-reduce functions but are still loadable.
        || matches!(
            pkg.as_str(),
            "future" | "futurize" | "future.apply" | "furrr" | "doFuture" | "progressr"
                | "iterators" | "future.callr" | "future.mirai" | "future.batchtools"
                | "parallel" | "utils" | "datasets"
        );
    if !known {
        return Err(Signal::error(format!("there is no package called '{pkg}'")));
    }
    Ok(RVal::scalar_bool(true))
}

fn require_namespace_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let pkg = args.bind(&["package"]).req(0, "package")?.as_str().map_err(Signal::error)?;
    Ok(RVal::scalar_bool(super::registry().packages.contains(&pkg.as_str())))
}

fn match_fun_fn(_i: &mut Interp, args: Args, env: &EnvRef) -> EvalResult {
    let f = args.bind(&["FUN"]).req(0, "FUN")?;
    match &f {
        RVal::Chr(_) => {
            let name = f.as_str().map_err(Signal::error)?;
            env::lookup(env, &name)
                .or_else(|| super::lookup_builtin(&name).map(|d| RVal::Builtin(d.id)))
                .ok_or_else(|| Signal::error(format!("could not find function \"{name}\"")))
        }
        _ if f.is_function() => Ok(f),
        other => Err(Signal::error(format!("not a function: {}", other.class()))),
    }
}

fn force_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    args.bind(&["x"]).req(0, "x")
}

fn negate_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    // Returns a marker the apply family understands; full closure
    // composition is not needed for the paper's examples.
    let f = args.bind(&["f"]).req(0, "f")?;
    let mut l = crate::rlite::value::RList::named(
        vec![f],
        vec!["f".into()],
    );
    l.class = Some("negated".into());
    Ok(RVal::List(l))
}

fn deparse_fn(_i: &mut Interp, args: Args, _env: &EnvRef) -> EvalResult {
    let x = args.bind(&["expr"]).req(0, "expr")?;
    Ok(RVal::scalar_str(format!("{x}")))
}

#[cfg(test)]
mod tests {
    use crate::rlite::eval::Interp;
    use crate::rlite::value::RVal;

    fn run(src: &str) -> RVal {
        Interp::new().eval_program(src).unwrap_or_else(|e| panic!("{src}: {e:?}"))
    }

    #[test]
    fn return_short_circuits() {
        assert_eq!(
            run("f <- function(x) { if (x > 0) return(\"pos\")\n\"neg\" }\nf(1)"),
            RVal::scalar_str("pos")
        );
    }

    #[test]
    fn local_scopes() {
        assert_eq!(run("x <- 1\ny <- local({ x <- 99\nx })\nc(x, y)"), RVal::dbl(vec![1.0, 99.0]));
    }

    #[test]
    fn switch_selects() {
        assert_eq!(run("switch(\"b\", a = 1, b = 2, 3)"), RVal::scalar_dbl(2.0));
        assert_eq!(run("switch(\"z\", a = 1, b = 2, 3)"), RVal::scalar_dbl(3.0));
    }

    #[test]
    fn library_known_and_unknown() {
        assert_eq!(run("library(future)"), RVal::scalar_bool(true));
        assert!(Interp::new().eval_program("library(nosuchpkg)").is_err());
    }

    #[test]
    fn quote_deparses() {
        assert_eq!(run("quote(lapply(xs, fcn))"), RVal::scalar_str("lapply(xs, fcn)"));
    }
}
