//! End-to-end over real worker subprocesses: scripts exercising the
//! full stack (parser → transpiler → process backend → PJRT payloads →
//! relay), including the paper's §4.9/§4.10 behaviours across the
//! process boundary.

use futurize::prelude::*;

fn session() -> Session {
    std::env::set_var(
        futurize::backend::worker::WORKER_BIN_ENV,
        env!("CARGO_BIN_EXE_futurize-rs"),
    );
    let mut s = Session::new();
    s.eval_str("plan(multisession, workers = 2)").unwrap();
    s
}

#[test]
fn closures_with_captured_state_cross_the_process_boundary() {
    let mut s = session();
    let v = s
        .eval_str(
            "base_val <- 100\nscale <- 3\nf <- function(x) (x + base_val) * scale\nunlist(lapply(1:4, f) |> futurize())",
        )
        .unwrap();
    assert_eq!(v.as_dbl_vec().unwrap(), vec![303.0, 306.0, 309.0, 312.0]);
}

#[test]
fn nested_closures_serialize() {
    let mut s = session();
    let v = s
        .eval_str(
            "make_adder <- function(k) function(x) x + k\nadd7 <- make_adder(7)\nunlist(lapply(1:3, add7) |> futurize())",
        )
        .unwrap();
    assert_eq!(v.as_dbl_vec().unwrap(), vec![8.0, 9.0, 10.0]);
}

#[test]
fn pjrt_kernels_run_inside_workers() {
    let mut s = session();
    let v = s
        .eval_str("unlist(lapply(list(c(0, 1), c(2, 3)), function(ch) sum(hlo_chunk_map(ch))) |> futurize())")
        .unwrap();
    // 3x^2+2x+1 at 0,1,2,3 = 1, 6, 17, 34.
    assert_eq!(v.as_dbl_vec().unwrap(), vec![7.0, 51.0]);
}

#[test]
fn progress_streams_near_live_from_processes() {
    let mut s = session();
    let exprs = futurize::rlite::parse_program(
        "xs <- 1:6\nys <- local({\n  p <- progressor(along = xs)\n  lapply(xs, function(x) { p()\nx })\n}) |> futurize()\nlength(ys)",
    )
    .unwrap();
    let genv = s.interp.global.clone();
    let mut progressions = 0;
    let mut last = RVal::Null;
    for e in &exprs {
        let (r, log) = s.interp.eval_captured(e, &genv);
        last = r.unwrap();
        progressions += log.conditions.iter().filter(|c| c.inherits("progression")).count();
    }
    assert_eq!(last.as_f64().unwrap(), 6.0);
    assert_eq!(progressions, 6, "one near-live progression per element");
}

#[test]
fn worker_crash_isolation_error_reported() {
    let mut s = session();
    // A task error must not poison the pool: subsequent calls succeed.
    let err = s
        .eval_str("lapply(1:2, function(x) stop(\"task-level failure\")) |> futurize()")
        .unwrap_err();
    assert!(err.contains("task-level failure"), "{err}");
    let v = s.eval_str("unlist(lapply(1:2, function(x) x) |> futurize())").unwrap();
    assert_eq!(v.as_dbl_vec().unwrap(), vec![1.0, 2.0]);
}

#[test]
fn boot_pipeline_end_to_end() {
    let mut s = session();
    s.eval_str("futureSeed(123)").unwrap();
    let v = s
        .eval_str(
            "data(bigcity)\nratio <- function(d, w) hlo_boot_stat(d$x, d$u, w)\n\
             b <- boot(bigcity, statistic = ratio, R = 60, stype = \"w\") |> futurize()\n\
             c(length(b$t), sum(b$t > 1), b$t0 > 1)",
        )
        .unwrap();
    let stats = v.as_dbl_vec().unwrap();
    assert_eq!(stats[0], 60.0);
    assert!(stats[1] > 50.0, "growth ratios should exceed 1: {stats:?}");
    assert_eq!(stats[2], 1.0);
}

#[test]
fn cli_run_subcommand_works() {
    let dir = std::env::temp_dir().join(format!("futurize-cli-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let script = dir.join("demo.R");
    std::fs::write(
        &script,
        "plan(multisession, workers = 2)\nsum(unlist(lapply(1:10, function(x) x^2) |> futurize()))\n",
    )
    .unwrap();
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_futurize-rs"))
        .args(["run", script.to_str().unwrap()])
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("385"), "stdout: {stdout}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn cli_supported_matches_paper_listing() {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_futurize-rs"))
        .args(["supported"])
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    for pkg in ["base", "purrr", "foreach", "plyr", "BiocParallel", "boot", "tm"] {
        assert!(stdout.contains(pkg), "missing {pkg} in: {stdout}");
    }
}
