//! Tests for the binary wire codec (`wire::bin`), the codec switch, and
//! the zero-copy `WireSlice` fast path:
//!
//! - exhaustive roundtrips over every `WireVal` variant (closures with
//!   captured bindings, conditions, NaN/±Inf doubles, non-ASCII
//!   strings) through both codecs;
//! - cross-codec agreement (JSON and binary decode to equal values);
//! - `WireVal::approx_size` regression against real encoded lengths;
//! - byte-reduction of binary over JSON on protocol streams;
//! - end-to-end multisession runs under the forced JSON debug codec.

use std::sync::Arc;

use futurize::backend::multisession::MultisessionBackend;
use futurize::prelude::*;
use futurize::rlite::serialize::{to_wire, WireSlice, WireVal};
use futurize::wire::{bin, WireCodec};

fn worker_env() {
    std::env::set_var(
        futurize::backend::worker::WORKER_BIN_ENV,
        env!("CARGO_BIN_EXE_futurize-rs"),
    );
}

/// Structural equality that treats NaN == NaN (WireVal's derived
/// `PartialEq` follows IEEE semantics, which would reject a perfectly
/// faithful NaN roundtrip).
fn wire_eq(a: &WireVal, b: &WireVal) -> bool {
    fn dbl_eq(x: &[f64], y: &[f64]) -> bool {
        x.len() == y.len()
            && x.iter().zip(y).all(|(a, b)| a.to_bits() == b.to_bits() || a == b)
    }
    match (a, b) {
        (WireVal::Dbl(x, nx), WireVal::Dbl(y, ny)) => dbl_eq(x, y) && nx == ny,
        (WireVal::List(x, nx, cx), WireVal::List(y, ny, cy)) => {
            nx == ny
                && cx == cy
                && x.len() == y.len()
                && x.iter().zip(y).all(|(a, b)| wire_eq(a, b))
        }
        (
            WireVal::Closure { params: pa, body: ba, captured: ca },
            WireVal::Closure { params: pb, body: bb, captured: cb },
        ) => {
            pa == pb
                && ba == bb
                && ca.len() == cb.len()
                && ca
                    .iter()
                    .zip(cb)
                    .all(|((na, va), (nb, vb))| na == nb && wire_eq(va, vb))
        }
        _ => a == b,
    }
}

/// One sample per `WireVal` variant, exercising the tricky corners.
/// Integer extremes stay within f64-exact range because the *JSON*
/// codec routes numbers through f64 (a pre-existing limitation of the
/// debug codec); full i64 range is covered by the binary-only test.
fn sample_values() -> Vec<WireVal> {
    let closure = {
        let mut i = futurize::rlite::eval::Interp::new();
        i.eval_program("a <- 10.5\nf <- function(z, k = 2) z * k + a").unwrap();
        let f = futurize::rlite::env::lookup(&i.global, "f").unwrap();
        to_wire(&f).unwrap()
    };
    let cond = WireVal::Cond(RCondition::custom(
        "progression",
        "étape ✓",
        Some(futurize::wire::JsonValue::obj(vec![
            ("amount", futurize::wire::JsonValue::num(1.0)),
            ("total", futurize::wire::JsonValue::num(10.0)),
        ])),
    ));
    vec![
        WireVal::Null,
        WireVal::Lgl(vec![], None),
        WireVal::Lgl(vec![true, false, true], Some(vec!["a".into(), "b".into(), "c".into()])),
        WireVal::Int(vec![0, -1, 1, 127, -128, 1 << 40, -(1 << 40), 1 << 62], None),
        WireVal::Dbl(
            vec![0.0, -0.0, 1.5, f64::NAN, f64::INFINITY, f64::NEG_INFINITY, 1e-308],
            Some((1..=7).map(|k| format!("n{k}")).collect()),
        ),
        WireVal::Chr(
            vec![
                "plain".into(),
                "non-ASCII: ✓ héllo 日本語".into(),
                "esc \"\\\n\t".into(),
                String::new(),
            ],
            None,
        ),
        WireVal::List(
            vec![
                WireVal::Dbl(vec![1.0], None),
                WireVal::List(vec![WireVal::Null], None, Some("inner".into())),
            ],
            Some(vec!["x".into(), "y".into()]),
            Some("data.frame".into()),
        ),
        closure,
        WireVal::Builtin("sum".into()),
        cond,
    ]
}

#[test]
fn every_wireval_variant_roundtrips_in_binary() {
    for w in sample_values() {
        let bytes = bin::to_bytes(&w).unwrap_or_else(|e| panic!("{w:?}: {e}"));
        let back: WireVal = bin::from_bytes(&bytes).unwrap_or_else(|e| panic!("{w:?}: {e}"));
        assert!(wire_eq(&w, &back), "binary roundtrip changed value:\n{w:?}\n{back:?}");
    }
}

#[test]
fn binary_roundtrips_full_i64_range() {
    // The JSON debug codec routes numbers through f64 and cannot
    // represent the i64 extremes; the binary codec must.
    let w = WireVal::Int(vec![i64::MIN, i64::MIN + 1, -1, 0, 1, i64::MAX - 1, i64::MAX], None);
    let back: WireVal = bin::from_bytes(&bin::to_bytes(&w).unwrap()).unwrap();
    assert_eq!(back, w);
}

#[test]
fn json_and_binary_decode_to_equal_values() {
    for w in sample_values() {
        let json = futurize::wire::to_string(&w).unwrap();
        let from_json: WireVal = futurize::wire::from_str(&json).unwrap();
        let from_bin: WireVal = bin::from_bytes(&bin::to_bytes(&w).unwrap()).unwrap();
        assert!(
            wire_eq(&from_json, &from_bin),
            "codecs disagree:\njson → {from_json:?}\nbin  → {from_bin:?}"
        );
    }
}

#[test]
fn closure_semantics_survive_binary_transport() {
    // Capture-by-value across the codec: mutate the global after
    // capture, decode on a "worker", and check the old value was kept.
    let mut i = futurize::rlite::eval::Interp::new();
    i.eval_program("a <- 10\nf <- function(x) x + a").unwrap();
    let f = futurize::rlite::env::lookup(&i.global, "f").unwrap();
    let w = to_wire(&f).unwrap();
    i.eval_program("a <- 999").unwrap();
    let decoded: WireVal = bin::from_bytes(&bin::to_bytes(&w).unwrap()).unwrap();
    let mut worker = futurize::rlite::eval::Interp::new();
    let g = futurize::rlite::serialize::from_wire(&decoded, &worker.global);
    futurize::rlite::env::define(&worker.global.clone(), "g", g);
    assert_eq!(worker.eval_program("g(5)").unwrap(), RVal::scalar_dbl(15.0));
}

// ---------------------------------------------------------------------------
// approx_size regression: the estimate must track real encoded lengths.
// ---------------------------------------------------------------------------

#[test]
fn approx_size_tracks_binary_encoded_length() {
    // Data variants use exact formulas; allow a small slack anyway so
    // the test pins behaviour, not byte-level trivia.
    let data_samples = vec![
        WireVal::Lgl(vec![true; 1000], None),
        WireVal::Lgl(vec![false; 10], Some((0..10).map(|k| format!("name{k}")).collect())),
        WireVal::Int((0..5000).collect(), None),
        WireVal::Int(vec![i64::MIN, i64::MAX, 0], None),
        WireVal::Dbl((0..2000).map(|k| k as f64 * 0.123456789).collect(), None),
        WireVal::Chr((0..200).map(|k| format!("string-{k}-✓")).collect(), None),
        WireVal::List(
            vec![
                WireVal::Dbl(vec![1.0; 64], None),
                WireVal::Int(vec![1, 2, 3], Some(vec!["a".into(), "b".into(), "c".into()])),
            ],
            Some(vec!["col1".into(), "col2".into()]),
            Some("data.frame".into()),
        ),
        WireVal::Null,
        WireVal::Builtin("sum".into()),
    ];
    for w in data_samples {
        let enc = bin::to_bytes(&w).unwrap().len() as i64;
        let approx = w.approx_size() as i64;
        let slack = (enc / 10).max(8);
        assert!(
            (approx - enc).abs() <= slack,
            "approx_size {approx} vs encoded {enc} (> {slack} off) for {w:?}"
        );
    }
    // Lgl must no longer undercount relative to its real footprint, and
    // names must be counted: a named vector is strictly bigger.
    let unnamed = WireVal::Lgl(vec![true; 100], None);
    let named = WireVal::Lgl(vec![true; 100], Some((0..100).map(|k| format!("n{k}")).collect()));
    assert!(named.approx_size() > unnamed.approx_size() + 300);
    // Estimated variants (closures, conditions) stay within a loose band.
    for w in sample_values() {
        let enc = bin::to_bytes(&w).unwrap().len() as f64;
        let approx = w.approx_size() as f64;
        assert!(
            approx >= enc * 0.25 - 64.0 && approx <= enc * 4.0 + 64.0,
            "approx_size {approx} wildly off encoded {enc} for {w:?}"
        );
    }
}

// ---------------------------------------------------------------------------
// Byte reduction: binary vs JSON on what multisession actually sends.
// ---------------------------------------------------------------------------

#[test]
fn binary_shrinks_the_protocol_stream_by_3x() {
    use futurize::backend::worker::{ParentMsg, WorkerMsg};
    use futurize::future_core::{ContextBody, TaskContext, TaskKind, TaskOutcome, TaskPayload};
    // A realistic numeric map call: one shared context (closure + a
    // 64-double global), 48 single-element chunks, 48 outcomes.
    let f = {
        let mut i = futurize::rlite::eval::Interp::new();
        i.eval_program("f <- function(x) x * 2").unwrap();
        to_wire(&futurize::rlite::env::lookup(&i.global, "f").unwrap()).unwrap()
    };
    let globals = vec![(
        "w".to_string(),
        WireVal::Dbl((0..64).map(|k| (k as f64).sin()).collect(), None),
    )];
    let ctx = TaskContext { id: 1, body: ContextBody::Map { f, extra: vec![] }, globals };
    let mut msgs_parent: Vec<ParentMsg> = vec![ParentMsg::RegisterContext(ctx)];
    let mut msgs_worker: Vec<WorkerMsg> = Vec::new();
    for k in 0..48u64 {
        msgs_parent.push(ParentMsg::Task(TaskPayload {
            id: k,
            kind: TaskKind::MapSlice {
                ctx: 1,
                items: vec![WireVal::Dbl(vec![(k as f64).cos()], None)].into(),
                seeds: None,
            },
            time_scale: 0.0,
            capture_stdout: true,
        }));
        msgs_worker.push(WorkerMsg::Done(TaskOutcome {
            id: k,
            values: Ok(vec![WireVal::Dbl(vec![2.0 * (k as f64).cos()], None)]),
            log: Default::default(),
            worker: (k % 2) as usize,
            started_unix: 1_769_000_000.123 + k as f64,
            finished_unix: 1_769_000_000.456 + k as f64,
        }));
    }
    let mut json_total = 0usize;
    let mut bin_total = 0usize;
    for m in &msgs_parent {
        json_total += WireCodec::Json.encode(m).unwrap().len();
        bin_total += WireCodec::Binary.encode(m).unwrap().len();
    }
    for m in &msgs_worker {
        json_total += WireCodec::Json.encode(m).unwrap().len();
        bin_total += WireCodec::Binary.encode(m).unwrap().len();
    }
    assert!(
        bin_total * 3 <= json_total,
        "expected ≥3× shrink: binary {bin_total} vs JSON {json_total}"
    );
}

#[test]
fn binary_shrinks_bulk_numeric_vectors() {
    // Bulk full-precision doubles: 8 B/elem binary vs ~19 B/elem JSON.
    let dbl = WireVal::Dbl((0..10_000).map(|k| (k as f64).sin()).collect(), None);
    let json = futurize::wire::to_string(&dbl).unwrap().len();
    let bin_len = bin::to_bytes(&dbl).unwrap().len();
    assert!(bin_len * 2 <= json, "doubles: binary {bin_len} vs JSON {json}");
    // Logical masks: 1 B/elem binary vs ~6 B/elem JSON.
    let lgl = WireVal::Lgl((0..10_000).map(|k| k % 3 == 0).collect(), None);
    let json = futurize::wire::to_string(&lgl).unwrap().len();
    let bin_len = bin::to_bytes(&lgl).unwrap().len();
    assert!(bin_len * 4 <= json, "logicals: binary {bin_len} vs JSON {json}");
}

// ---------------------------------------------------------------------------
// Zero-copy WireSlice: shared windows alias the frozen storage.
// ---------------------------------------------------------------------------

#[test]
fn shared_wire_slices_alias_their_source() {
    let elems: Vec<WireVal> = (0..100).map(|k| WireVal::Dbl(vec![k as f64], None)).collect();
    let source = Arc::new(elems);
    let slice = WireSlice::shared(source.clone(), 10, 20);
    assert_eq!(slice.len(), 10);
    // The window reads the very same elements — no clone happened.
    assert!(std::ptr::eq(&source[10], &slice.as_slice()[0]));
    assert!(std::ptr::eq(&source[19], &slice.as_slice()[9]));
    // Many windows over one source cost Arc bumps only.
    let windows: Vec<_> =
        (0..10).map(|k| WireSlice::shared(source.clone(), k * 10, (k + 1) * 10)).collect();
    assert_eq!(Arc::strong_count(&source), 12); // source + slice + 10 windows
    drop(windows);
    drop(slice);
    assert_eq!(Arc::strong_count(&source), 1);
}

// ---------------------------------------------------------------------------
// End-to-end: the forced JSON debug codec still passes the pipeline.
// ---------------------------------------------------------------------------

#[test]
fn multisession_works_under_forced_json_codec() {
    worker_env();
    let reference = Session::new()
        .eval_str("unlist(lapply(1:12, function(x) x^2 + 1))")
        .unwrap();
    for codec in [WireCodec::Binary, WireCodec::Json] {
        let mut s = Session::new();
        s.eval_str("plan(multisession, workers = 2)").unwrap();
        let backend = MultisessionBackend::with_codec(2, "multisession", codec).unwrap();
        s.interp.session.install_backend(Box::new(backend));
        let v = s
            .eval_str("unlist(lapply(1:12, function(x) x^2 + 1) |> futurize())")
            .unwrap_or_else(|e| panic!("{codec:?}: {e}"));
        assert_eq!(v, reference, "{codec:?}");
    }
}

#[test]
fn json_codec_costs_more_bytes_than_binary_end_to_end() {
    worker_env();
    let run = |codec: WireCodec| -> u64 {
        let mut s = Session::new();
        s.eval_str("plan(multisession, workers = 2)").unwrap();
        let backend = MultisessionBackend::with_codec(2, "multisession", codec).unwrap();
        s.interp.session.install_backend(Box::new(backend));
        s.eval_str("big <- 1:5000\nf <- function(x) x + length(big) * 0").unwrap();
        s.eval_str("invisible(lapply(1:2, f) |> futurize())").unwrap(); // warm pool
        futurize::wire::stats::reset();
        s.eval_str("invisible(lapply(1:24, f) |> futurize(scheduling = Inf))").unwrap();
        futurize::wire::stats::bytes()
    };
    let bin_bytes = run(WireCodec::Binary);
    let json_bytes = run(WireCodec::Json);
    assert!(
        bin_bytes * 2 <= json_bytes,
        "binary transport should cost well under half of JSON: {bin_bytes} vs {json_bytes}"
    );
}
