//! Tests for the binary wire codec (`wire::bin`), the codec switch, and
//! the zero-copy `WireSlice` fast path:
//!
//! - property-based roundtrips: a seeded deterministic generator builds
//!   hundreds of arbitrary `WireVal` trees (closures with captured
//!   bindings, conditions, NaN bit patterns/±Inf doubles, non-ASCII
//!   names, deep list chains, shared `WireSlice` windows) and checks
//!   them through both codecs — replacing the old hand-picked samples;
//! - cross-codec agreement (JSON and binary decode to equal values);
//! - `WireVal::approx_size` regression against real encoded lengths;
//! - byte-reduction of binary over JSON on protocol streams;
//! - end-to-end multisession runs under the forced JSON debug codec.

use std::sync::Arc;

use futurize::backend::multisession::MultisessionBackend;
use futurize::prelude::*;
use futurize::rlite::serialize::{to_wire, WireSlice, WireVal};
use futurize::wire::{bin, WireCodec};

fn worker_env() {
    std::env::set_var(
        futurize::backend::worker::WORKER_BIN_ENV,
        env!("CARGO_BIN_EXE_futurize-rs"),
    );
}

/// Structural equality that treats NaN == NaN (WireVal's derived
/// `PartialEq` follows IEEE semantics, which would reject a perfectly
/// faithful NaN roundtrip).
fn wire_eq(a: &WireVal, b: &WireVal) -> bool {
    fn dbl_eq(x: &[f64], y: &[f64]) -> bool {
        x.len() == y.len()
            && x.iter().zip(y).all(|(a, b)| a.to_bits() == b.to_bits() || a == b)
    }
    match (a, b) {
        (WireVal::Dbl(x, nx), WireVal::Dbl(y, ny)) => dbl_eq(x, y) && nx == ny,
        (WireVal::List(x, nx, cx), WireVal::List(y, ny, cy)) => {
            nx == ny
                && cx == cy
                && x.len() == y.len()
                && x.iter().zip(y).all(|(a, b)| wire_eq(a, b))
        }
        (
            WireVal::Closure { params: pa, body: ba, captured: ca },
            WireVal::Closure { params: pb, body: bb, captured: cb },
        ) => {
            pa == pb
                && ba == bb
                && ca.len() == cb.len()
                && ca
                    .iter()
                    .zip(cb)
                    .all(|((na, va), (nb, vb))| na == nb && wire_eq(va, vb))
        }
        _ => a == b,
    }
}

// ---------------------------------------------------------------------------
// Property-based value generation: a seeded deterministic generator of
// arbitrary WireVal trees replaces the old hand-picked sample list, so
// the roundtrip/cross-codec properties are checked over hundreds of
// structurally diverse values (every failure reprints the offending
// value and is reproducible from the fixed seed).
// ---------------------------------------------------------------------------

/// xorshift64* — tiny, dependency-free, deterministic.
struct Gen(u64);

impl Gen {
    fn new(seed: u64) -> Gen {
        Gen(seed | 1)
    }

    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    fn below(&mut self, n: u64) -> usize {
        (self.next() % n.max(1)) as usize
    }

    fn chance(&mut self, percent: u64) -> bool {
        self.next() % 100 < percent
    }
}

/// What a corpus may contain. The JSON debug codec routes numbers
/// through f64 (pre-existing limitation), so corpora that cross-check
/// against JSON keep integers f64-exact; the binary-only corpus uses the
/// full i64 range. `data_only` skips closures/conditions for properties
/// that only hold exactly on data variants (approx_size).
#[derive(Clone, Copy)]
struct GenCfg {
    f64_exact_ints: bool,
    data_only: bool,
}

fn gen_string(g: &mut Gen) -> String {
    const POOL: &[&str] = &[
        "plain",
        "",
        "non-ASCII: ✓ héllo 日本語",
        "esc \"\\\n\t",
        "emoji 🔀🧵",
        "ünïcode-名前",
        "with space and 'quotes'",
    ];
    if g.chance(60) {
        POOL[g.below(POOL.len() as u64)].to_string()
    } else {
        let n = g.below(12);
        (0..n).map(|_| (b'a' + g.below(26) as u8) as char).collect()
    }
}

fn gen_names(g: &mut Gen, len: usize) -> Option<Vec<String>> {
    if g.chance(40) {
        Some((0..len).map(|_| gen_string(g)).collect())
    } else {
        None
    }
}

fn gen_dbl(g: &mut Gen, cfg: &GenCfg) -> f64 {
    const POOL: &[f64] = &[
        0.0,
        -0.0,
        1.5,
        -1.0,
        f64::NAN,
        f64::INFINITY,
        f64::NEG_INFINITY,
        f64::MIN_POSITIVE,
        1e-308,
        1.5e300,
        std::f64::consts::PI,
    ];
    if g.chance(50) {
        POOL[g.below(POOL.len() as u64)]
    } else if cfg.f64_exact_ints {
        // JSON-safe corpus: arbitrary finite doubles (the text codec
        // canonicalizes NaN payloads, so exotic bit patterns are a
        // binary-only property).
        (g.next() as i64) as f64 * 1.0e-3
    } else {
        f64::from_bits(g.next()) // arbitrary bit patterns, NaN payloads included
    }
}

fn gen_int(g: &mut Gen, cfg: &GenCfg) -> i64 {
    if cfg.f64_exact_ints {
        // ±2^51: exactly representable in f64, so the JSON number path
        // cannot lose them.
        (g.next() % (1 << 52)) as i64 - (1 << 51)
    } else {
        g.next() as i64
    }
}

/// A small pool of real serialized closures (params with defaults,
/// captured bindings) whose captured values are re-randomized per draw.
fn gen_closure(g: &mut Gen, cfg: &GenCfg) -> WireVal {
    let srcs = [
        "function(z, k = 2) z * k + a",
        "function(x) x + a",
        "function() a",
    ];
    let src = srcs[g.below(srcs.len() as u64)];
    let mut i = futurize::rlite::eval::Interp::new();
    i.eval_program(&format!("a <- 1\nf <- {src}")).unwrap();
    let f = futurize::rlite::env::lookup(&i.global, "f").unwrap();
    let WireVal::Closure { params, body, .. } = to_wire(&f).unwrap() else {
        panic!("closure expected")
    };
    let captured = vec![("a".to_string(), arbitrary(g, 0, cfg))];
    WireVal::Closure { params, body, captured }
}

/// One arbitrary WireVal tree: leaves at depth 0, otherwise any variant
/// including nested lists (deep chains when the dice cooperate).
fn arbitrary(g: &mut Gen, depth: usize, cfg: &GenCfg) -> WireVal {
    let n_variants = if depth == 0 { 5 } else { 8 };
    match g.below(n_variants) {
        0 => WireVal::Null,
        1 => {
            let n = g.below(6);
            let vals = (0..n).map(|_| g.chance(50)).collect();
            let names = gen_names(g, n);
            WireVal::Lgl(vals, names)
        }
        2 => {
            let n = g.below(6);
            let vals = (0..n).map(|_| gen_int(g, cfg)).collect();
            let names = gen_names(g, n);
            WireVal::Int(vals, names)
        }
        3 => {
            let n = g.below(6);
            let vals = (0..n).map(|_| gen_dbl(g, cfg)).collect();
            let names = gen_names(g, n);
            WireVal::Dbl(vals, names)
        }
        4 => {
            let n = g.below(5);
            let vals = (0..n).map(|_| gen_string(g)).collect();
            let names = gen_names(g, n);
            WireVal::Chr(vals, names)
        }
        5 => {
            let n = g.below(4);
            let vals = (0..n).map(|_| arbitrary(g, depth - 1, cfg)).collect();
            let names = gen_names(g, n);
            let class = if g.chance(30) { Some(gen_string(g)) } else { None };
            WireVal::List(vals, names, class)
        }
        6 if !cfg.data_only => gen_closure(g, cfg),
        7 if !cfg.data_only => WireVal::Cond(RCondition::custom(
            "progression",
            &gen_string(g),
            Some(futurize::wire::JsonValue::obj(vec![
                ("amount", futurize::wire::JsonValue::num(1.0)),
                ("total", futurize::wire::JsonValue::num(10.0)),
            ])),
        )),
        _ => WireVal::Builtin(["sum", "length", "identity"][g.below(3)].to_string()),
    }
}

/// `n` arbitrary trees from a fixed seed, always prepending a maximally
/// deep list chain so deep recursion is in every run, not left to dice.
fn fuzz_corpus(seed: u64, n: usize, cfg: GenCfg) -> Vec<WireVal> {
    let mut g = Gen::new(seed);
    let mut out = Vec::with_capacity(n + 1);
    let mut deep = arbitrary(&mut g, 0, &cfg);
    for k in 0..12 {
        deep = WireVal::List(
            vec![deep],
            Some(vec![format!("lvl{k}")]),
            if k % 3 == 0 { Some("wrap".into()) } else { None },
        );
    }
    out.push(deep);
    for _ in 0..n {
        out.push(arbitrary(&mut g, 4, &cfg));
    }
    out
}

const CROSS_CODEC_CFG: GenCfg = GenCfg { f64_exact_ints: true, data_only: false };
const BINARY_ONLY_CFG: GenCfg = GenCfg { f64_exact_ints: false, data_only: false };
const DATA_ONLY_CFG: GenCfg = GenCfg { f64_exact_ints: true, data_only: true };

#[test]
fn arbitrary_wirevals_roundtrip_in_binary() {
    // Full i64 range, NaN bit patterns, deep lists, non-ASCII names.
    for w in fuzz_corpus(0xF00D, 300, BINARY_ONLY_CFG) {
        let bytes = bin::to_bytes(&w).unwrap_or_else(|e| panic!("{w:?}: {e}"));
        let back: WireVal = bin::from_bytes(&bytes).unwrap_or_else(|e| panic!("{w:?}: {e}"));
        assert!(wire_eq(&w, &back), "binary roundtrip changed value:\n{w:?}\n{back:?}");
    }
}

#[test]
fn arbitrary_wirevals_roundtrip_in_json() {
    for w in fuzz_corpus(0xBEEF, 200, CROSS_CODEC_CFG) {
        let json = futurize::wire::to_string(&w).unwrap_or_else(|e| panic!("{w:?}: {e}"));
        let back: WireVal =
            futurize::wire::from_str(&json).unwrap_or_else(|e| panic!("{w:?}: {e}"));
        assert!(wire_eq(&w, &back), "JSON roundtrip changed value:\n{w:?}\n{back:?}");
    }
}

#[test]
fn binary_roundtrips_full_i64_range() {
    // The JSON debug codec routes numbers through f64 and cannot
    // represent the i64 extremes; the binary codec must.
    let w = WireVal::Int(vec![i64::MIN, i64::MIN + 1, -1, 0, 1, i64::MAX - 1, i64::MAX], None);
    let back: WireVal = bin::from_bytes(&bin::to_bytes(&w).unwrap()).unwrap();
    assert_eq!(back, w);
}

#[test]
fn json_and_binary_decode_to_equal_values() {
    for w in fuzz_corpus(0xCAFE, 200, CROSS_CODEC_CFG) {
        let json = futurize::wire::to_string(&w).unwrap();
        let from_json: WireVal = futurize::wire::from_str(&json).unwrap();
        let from_bin: WireVal = bin::from_bytes(&bin::to_bytes(&w).unwrap()).unwrap();
        assert!(
            wire_eq(&from_json, &from_bin),
            "codecs disagree on {w:?}:\njson → {from_json:?}\nbin  → {from_bin:?}"
        );
    }
}

#[test]
fn shared_wire_slices_roundtrip_like_their_window() {
    // A Shared window must encode exactly like the owned window contents
    // in BOTH codecs, and decode to an Owned slice with equal elements.
    let mut g = Gen::new(0xD1CE);
    for _ in 0..25 {
        let n = 2 + g.below(8);
        let elems: Vec<WireVal> =
            (0..n).map(|_| arbitrary(&mut g, 2, &CROSS_CODEC_CFG)).collect();
        let source = Arc::new(elems);
        let start = g.below(source.len() as u64);
        let end = start + 1 + g.below((source.len() - start) as u64);
        let shared: WireSlice<WireVal> = WireSlice::shared(source.clone(), start, end);
        let owned: WireSlice<WireVal> = WireSlice::from(source[start..end].to_vec());
        type Roundtrip = fn(&WireSlice<WireVal>) -> (Vec<u8>, WireSlice<WireVal>);
        let roundtrips: [Roundtrip; 2] = [
            |s| {
                let b = bin::to_bytes(s).unwrap();
                let back = bin::from_bytes(&b).unwrap();
                (b, back)
            },
            |s| {
                let j = futurize::wire::to_string(s).unwrap();
                let back = futurize::wire::from_str(&j).unwrap();
                (j.into_bytes(), back)
            },
        ];
        for roundtrip in roundtrips {
            let (shared_bytes, back) = roundtrip(&shared);
            let (owned_bytes, _) = roundtrip(&owned);
            assert_eq!(shared_bytes, owned_bytes, "shared window must encode as its contents");
            assert!(matches!(back, WireSlice::Owned(_)), "decode is always Owned");
            assert_eq!(back.len(), end - start);
            for (a, b) in back.as_slice().iter().zip(&source[start..end]) {
                assert!(wire_eq(a, b), "slice element changed:\n{a:?}\n{b:?}");
            }
        }
    }
}

#[test]
fn closure_semantics_survive_binary_transport() {
    // Capture-by-value across the codec: mutate the global after
    // capture, decode on a "worker", and check the old value was kept.
    let mut i = futurize::rlite::eval::Interp::new();
    i.eval_program("a <- 10\nf <- function(x) x + a").unwrap();
    let f = futurize::rlite::env::lookup(&i.global, "f").unwrap();
    let w = to_wire(&f).unwrap();
    i.eval_program("a <- 999").unwrap();
    let decoded: WireVal = bin::from_bytes(&bin::to_bytes(&w).unwrap()).unwrap();
    let mut worker = futurize::rlite::eval::Interp::new();
    let g = futurize::rlite::serialize::from_wire(&decoded, &worker.global);
    futurize::rlite::env::define(&worker.global.clone(), "g", g);
    assert_eq!(worker.eval_program("g(5)").unwrap(), RVal::scalar_dbl(15.0));
}

// ---------------------------------------------------------------------------
// approx_size regression: the estimate must track real encoded lengths.
// ---------------------------------------------------------------------------

#[test]
fn approx_size_tracks_binary_encoded_length() {
    // Data variants use exact formulas; allow a small slack anyway so
    // the test pins behaviour, not byte-level trivia.
    let data_samples = vec![
        WireVal::Lgl(vec![true; 1000], None),
        WireVal::Lgl(vec![false; 10], Some((0..10).map(|k| format!("name{k}")).collect())),
        WireVal::Int((0..5000).collect(), None),
        WireVal::Int(vec![i64::MIN, i64::MAX, 0], None),
        WireVal::Dbl((0..2000).map(|k| k as f64 * 0.123456789).collect(), None),
        WireVal::Chr((0..200).map(|k| format!("string-{k}-✓")).collect(), None),
        WireVal::List(
            vec![
                WireVal::Dbl(vec![1.0; 64], None),
                WireVal::Int(vec![1, 2, 3], Some(vec!["a".into(), "b".into(), "c".into()])),
            ],
            Some(vec!["col1".into(), "col2".into()]),
            Some("data.frame".into()),
        ),
        WireVal::Null,
        WireVal::Builtin("sum".into()),
    ];
    for w in data_samples {
        let enc = bin::to_bytes(&w).unwrap().len() as i64;
        let approx = w.approx_size() as i64;
        let slack = (enc / 10).max(8);
        assert!(
            (approx - enc).abs() <= slack,
            "approx_size {approx} vs encoded {enc} (> {slack} off) for {w:?}"
        );
    }
    // Lgl must no longer undercount relative to its real footprint, and
    // names must be counted: a named vector is strictly bigger.
    let unnamed = WireVal::Lgl(vec![true; 100], None);
    let named = WireVal::Lgl(vec![true; 100], Some((0..100).map(|k| format!("n{k}")).collect()));
    assert!(named.approx_size() > unnamed.approx_size() + 300);
    // Arbitrary data-only trees stay near-exact (the formulas mirror the
    // binary encoding; small slack keeps this a behaviour pin, not a
    // byte-level one).
    for w in fuzz_corpus(0xA55E7, 120, DATA_ONLY_CFG) {
        let enc = bin::to_bytes(&w).unwrap().len() as i64;
        let approx = w.approx_size() as i64;
        let slack = (enc / 10).max(8);
        assert!(
            (approx - enc).abs() <= slack,
            "approx_size {approx} vs encoded {enc} (> {slack} off) for {w:?}"
        );
    }
    // Estimated variants (closures, conditions) stay within a loose band.
    let mut g = Gen::new(0x10af);
    let estimated = vec![
        gen_closure(&mut g, &CROSS_CODEC_CFG),
        WireVal::Cond(RCondition::custom(
            "progression",
            "étape ✓",
            Some(futurize::wire::JsonValue::obj(vec![
                ("amount", futurize::wire::JsonValue::num(1.0)),
                ("total", futurize::wire::JsonValue::num(10.0)),
            ])),
        )),
    ];
    for w in estimated {
        let enc = bin::to_bytes(&w).unwrap().len() as f64;
        let approx = w.approx_size() as f64;
        assert!(
            approx >= enc * 0.25 - 64.0 && approx <= enc * 4.0 + 64.0,
            "approx_size {approx} wildly off encoded {enc} for {w:?}"
        );
    }
}

// ---------------------------------------------------------------------------
// Byte reduction: binary vs JSON on what multisession actually sends.
// ---------------------------------------------------------------------------

#[test]
fn binary_shrinks_the_protocol_stream_by_3x() {
    use futurize::backend::worker::{ParentMsg, WorkerMsg};
    use futurize::future_core::{ContextBody, TaskContext, TaskKind, TaskOutcome, TaskPayload};
    // A realistic numeric map call: one shared context (closure + a
    // 64-double global), 48 single-element chunks, 48 outcomes.
    let f = {
        let mut i = futurize::rlite::eval::Interp::new();
        i.eval_program("f <- function(x) x * 2").unwrap();
        to_wire(&futurize::rlite::env::lookup(&i.global, "f").unwrap()).unwrap()
    };
    let globals = vec![(
        "w".to_string(),
        WireVal::Dbl((0..64).map(|k| (k as f64).sin()).collect(), None),
    )];
    let ctx = TaskContext {
        id: 1,
        body: ContextBody::Map { f, extra: vec![] },
        globals,
        cached_globals: vec![],
        nesting: Default::default(),
        kernel: None,
        reduce: None,
    };
    let mut msgs_parent: Vec<ParentMsg> = vec![ParentMsg::RegisterContext(ctx)];
    let mut msgs_worker: Vec<WorkerMsg> = Vec::new();
    for k in 0..48u64 {
        msgs_parent.push(ParentMsg::Task(TaskPayload {
            id: k,
            kind: TaskKind::MapSlice {
                ctx: 1,
                items: vec![WireVal::Dbl(vec![(k as f64).cos()], None)].into(),
                seeds: None,
            },
            time_scale: 0.0,
            capture_stdout: true,
        }));
        msgs_worker.push(WorkerMsg::Done(TaskOutcome {
            id: k,
            values: Ok(vec![WireVal::Dbl(vec![2.0 * (k as f64).cos()], None)]),
            log: Default::default(),
            worker: (k % 2) as usize,
            started_unix: 1_769_000_000.123 + k as f64,
            finished_unix: 1_769_000_000.456 + k as f64,
            nested_workers: 0,
            partial: None,
        }));
    }
    let mut json_total = 0usize;
    let mut bin_total = 0usize;
    for m in &msgs_parent {
        json_total += WireCodec::Json.encode(m).unwrap().len();
        bin_total += WireCodec::Binary.encode(m).unwrap().len();
    }
    for m in &msgs_worker {
        json_total += WireCodec::Json.encode(m).unwrap().len();
        bin_total += WireCodec::Binary.encode(m).unwrap().len();
    }
    assert!(
        bin_total * 3 <= json_total,
        "expected ≥3× shrink: binary {bin_total} vs JSON {json_total}"
    );
}

#[test]
fn binary_shrinks_bulk_numeric_vectors() {
    // Bulk full-precision doubles: 8 B/elem binary vs ~19 B/elem JSON.
    let dbl = WireVal::Dbl((0..10_000).map(|k| (k as f64).sin()).collect(), None);
    let json = futurize::wire::to_string(&dbl).unwrap().len();
    let bin_len = bin::to_bytes(&dbl).unwrap().len();
    assert!(bin_len * 2 <= json, "doubles: binary {bin_len} vs JSON {json}");
    // Logical masks: 1 B/elem binary vs ~6 B/elem JSON.
    let lgl = WireVal::Lgl((0..10_000).map(|k| k % 3 == 0).collect(), None);
    let json = futurize::wire::to_string(&lgl).unwrap().len();
    let bin_len = bin::to_bytes(&lgl).unwrap().len();
    assert!(bin_len * 4 <= json, "logicals: binary {bin_len} vs JSON {json}");
}

// ---------------------------------------------------------------------------
// Zero-copy WireSlice: shared windows alias the frozen storage.
// ---------------------------------------------------------------------------

#[test]
fn shared_wire_slices_alias_their_source() {
    let elems: Vec<WireVal> = (0..100).map(|k| WireVal::Dbl(vec![k as f64], None)).collect();
    let source = Arc::new(elems);
    let slice = WireSlice::shared(source.clone(), 10, 20);
    assert_eq!(slice.len(), 10);
    // The window reads the very same elements — no clone happened.
    assert!(std::ptr::eq(&source[10], &slice.as_slice()[0]));
    assert!(std::ptr::eq(&source[19], &slice.as_slice()[9]));
    // Many windows over one source cost Arc bumps only.
    let windows: Vec<_> =
        (0..10).map(|k| WireSlice::shared(source.clone(), k * 10, (k + 1) * 10)).collect();
    assert_eq!(Arc::strong_count(&source), 12); // source + slice + 10 windows
    drop(windows);
    drop(slice);
    assert_eq!(Arc::strong_count(&source), 1);
}

// ---------------------------------------------------------------------------
// End-to-end: the forced JSON debug codec still passes the pipeline.
// ---------------------------------------------------------------------------

#[test]
fn multisession_works_under_forced_json_codec() {
    worker_env();
    let reference = Session::new()
        .eval_str("unlist(lapply(1:12, function(x) x^2 + 1))")
        .unwrap();
    for codec in [WireCodec::Binary, WireCodec::Json] {
        let mut s = Session::new();
        s.eval_str("plan(multisession, workers = 2)").unwrap();
        let backend = MultisessionBackend::with_codec(2, "multisession", codec).unwrap();
        s.interp.session.install_backend(Box::new(backend));
        let v = s
            .eval_str("unlist(lapply(1:12, function(x) x^2 + 1) |> futurize())")
            .unwrap_or_else(|e| panic!("{codec:?}: {e}"));
        assert_eq!(v, reference, "{codec:?}");
    }
}

// ---------------------------------------------------------------------------
// Frame layer: the length-prefix cap guards every process transport.
// ---------------------------------------------------------------------------

#[test]
fn oversize_frame_is_a_protocol_error_not_an_allocation() {
    use futurize::wire::codec::{read_frame, read_frame_capped, write_frame};
    use std::io::Cursor;
    // A frame within the cap roundtrips through the explicit-cap reader
    // and through the default (env-capped, 256 MiB) reader.
    let mut buf = Vec::new();
    write_frame(&mut buf, &[7u8; 1024]).unwrap();
    assert_eq!(
        read_frame_capped(&mut Cursor::new(&buf), 4096).unwrap().unwrap(),
        vec![7u8; 1024]
    );
    assert_eq!(read_frame(&mut Cursor::new(&buf)).unwrap().unwrap().len(), 1024);
    // A length prefix above the cap must error before allocating: a
    // desynced or hostile stream advertising a multi-GiB frame would
    // otherwise commit the allocation before the decode could fail.
    let mut big = Vec::new();
    write_frame(&mut big, &[0u8; 2048]).unwrap();
    let err = read_frame_capped(&mut Cursor::new(&big), 1024).unwrap_err();
    assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
    assert!(
        err.to_string().contains("exceeds cap"),
        "error should name the cap breach: {err}"
    );
    // Clean EOF (no header bytes) is Ok(None); a truncated header is an
    // error — the two must stay distinguishable for supervision.
    assert!(read_frame_capped(&mut Cursor::new(&[]), 1024).unwrap().is_none());
    let err = read_frame_capped(&mut Cursor::new(&[1u8, 0]), 1024).unwrap_err();
    assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof);
}

#[test]
fn json_codec_costs_more_bytes_than_binary_end_to_end() {
    worker_env();
    let run = |codec: WireCodec| -> u64 {
        let mut s = Session::new();
        s.eval_str("plan(multisession, workers = 2)").unwrap();
        let backend = MultisessionBackend::with_codec(2, "multisession", codec).unwrap();
        s.interp.session.install_backend(Box::new(backend));
        s.eval_str("big <- 1:5000\nf <- function(x) x + length(big) * 0").unwrap();
        s.eval_str("invisible(lapply(1:2, f) |> futurize())").unwrap(); // warm pool
        futurize::wire::stats::reset();
        s.eval_str("invisible(lapply(1:24, f) |> futurize(scheduling = Inf))").unwrap();
        futurize::wire::stats::bytes()
    };
    let bin_bytes = run(WireCodec::Binary);
    let json_bytes = run(WireCodec::Json);
    assert!(
        bin_bytes * 2 <= json_bytes,
        "binary transport should cost well under half of JSON: {bin_bytes} vs {json_bytes}"
    );
}
