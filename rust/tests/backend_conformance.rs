//! The future.tests analog (paper §2.1 footnote 2): every backend must
//! be compliant with the Future API. One conformance suite, run against
//! all five backends.

use futurize::prelude::*;

fn worker_env() {
    // Integration tests run inside the libtest harness binary, which
    // cannot host workers; point multisession at the real CLI binary.
    std::env::set_var(
        futurize::backend::worker::WORKER_BIN_ENV,
        env!("CARGO_BIN_EXE_futurize-rs"),
    );
}

const PLANS: &[&str] = &[
    "sequential",
    "multicore, workers = 2",
    "multisession, workers = 2",
    "cluster, workers = c(\"n1\", \"n2\"), latency_ms = 0.1",
    "future.batchtools::batchtools_slurm, workers = 2, poll_ms = 2",
];

fn for_each_plan(f: impl Fn(&mut Session, &str)) {
    worker_env();
    for plan in PLANS {
        let mut s = Session::new();
        s.eval_str(&format!("plan({plan})")).unwrap();
        f(&mut s, plan);
    }
}

#[test]
fn values_match_sequential_reference() {
    worker_env();
    let reference = Session::new()
        .eval_str("unlist(lapply(1:12, function(x) x^2 + 1))")
        .unwrap();
    for_each_plan(|s, plan| {
        let v = s
            .eval_str("unlist(lapply(1:12, function(x) x^2 + 1) |> futurize())")
            .unwrap_or_else(|e| panic!("{plan}: {e}"));
        assert_eq!(v, reference, "{plan}");
    });
}

#[test]
fn globals_are_exported_by_value() {
    for_each_plan(|s, plan| {
        let v = s
            .eval_str(
                "a <- 10\nf <- function(x) x + a\nr <- lapply(1:3, f) |> futurize()\na <- 999\nunlist(r)",
            )
            .unwrap_or_else(|e| panic!("{plan}: {e}"));
        assert_eq!(v.as_dbl_vec().unwrap(), vec![11.0, 12.0, 13.0], "{plan}");
    });
}

#[test]
fn errors_preserve_the_original_condition() {
    // The paper's §1 critique: mclapply/parLapply lose the error object.
    for_each_plan(|s, plan| {
        let v = s
            .eval_str(
                "r <- tryCatch(\n  lapply(1:4, function(x) if (x == 3) stop(\"original message\") else x) |> futurize(),\n  error = function(e) conditionMessage(e))\nr",
            )
            .unwrap_or_else(|e| panic!("{plan}: {e}"));
        assert_eq!(v.as_str().unwrap(), "original message", "{plan}");
    });
}

#[test]
fn stdout_and_messages_relay() {
    for_each_plan(|s, plan| {
        let (r, out) = s.eval_captured(
            "ys <- lapply(1:2, function(x) { cat(\"o\", x, \"\")\nmessage(\"m\", x)\nx }) |> futurize()\nunlist(ys)",
        );
        let v = r.unwrap_or_else(|e| panic!("{plan}: {e}"));
        assert_eq!(v.as_dbl_vec().unwrap(), vec![1.0, 2.0], "{plan}");
        assert!(out.contains("o 1"), "{plan}: stdout lost: {out:?}");
        assert!(out.contains("m1"), "{plan}: message lost: {out:?}");
    });
}

#[test]
fn warnings_relay_and_are_suppressible() {
    for_each_plan(|s, plan| {
        let (_, noisy) = s.eval_captured(
            "ys <- lapply(1:2, function(x) { warning(\"w\", x)\nx }) |> futurize()",
        );
        assert!(noisy.contains("w1"), "{plan}: warning lost: {noisy:?}");
        let (_, quiet) = s.eval_captured(
            "ys <- lapply(1:2, function(x) { warning(\"w\", x)\nx }) |> suppressWarnings() |> futurize()",
        );
        assert!(!quiet.contains("w1"), "{plan}: suppression failed: {quiet:?}");
    });
}

#[test]
fn seed_true_reproducible_per_backend() {
    worker_env();
    let reference = {
        let mut s = Session::new();
        s.eval_str("futureSeed(31)").unwrap();
        s.eval_str("unlist(lapply(1:8, function(x) rnorm(1)) |> futurize(seed = TRUE))")
            .unwrap()
    };
    for_each_plan(|s, plan| {
        s.eval_str("futureSeed(31)").unwrap();
        let v = s
            .eval_str("unlist(lapply(1:8, function(x) rnorm(1)) |> futurize(seed = TRUE))")
            .unwrap_or_else(|e| panic!("{plan}: {e}"));
        assert_eq!(v, reference, "{plan}: RNG streams must be backend-invariant");
    });
}

#[test]
fn chunking_options_respected() {
    for_each_plan(|s, plan| {
        for opts in ["chunk_size = 1", "chunk_size = 5", "scheduling = Inf", "scheduling = 2"] {
            let v = s
                .eval_str(&format!(
                    "unlist(lapply(1:10, function(x) x * 2) |> futurize({opts}))"
                ))
                .unwrap_or_else(|e| panic!("{plan}/{opts}: {e}"));
            assert_eq!(
                v.as_dbl_vec().unwrap(),
                (1..=10).map(|x| (x * 2) as f64).collect::<Vec<_>>(),
                "{plan}/{opts}"
            );
        }
    });
}

#[test]
fn low_level_future_api_works_everywhere() {
    for_each_plan(|s, plan| {
        let v = s
            .eval_str("f1 <- future(1 + 1)\nf2 <- future(2 + 2)\nvalue(f1) + value(f2)")
            .unwrap_or_else(|e| panic!("{plan}: {e}"));
        assert_eq!(v.as_f64().unwrap(), 6.0, "{plan}");
    });
}

#[test]
fn empty_input_yields_empty_result() {
    for_each_plan(|s, plan| {
        let v = s
            .eval_str("r <- lapply(NULL, function(x) x) |> futurize()\nlength(r)")
            .unwrap_or_else(|e| panic!("{plan}: {e}"));
        assert_eq!(v.as_f64().unwrap(), 0.0, "{plan}");
    });
}

#[test]
fn plan_switching_mid_session() {
    worker_env();
    let mut s = Session::new();
    let mut results = Vec::new();
    for plan in PLANS {
        s.eval_str(&format!("plan({plan})")).unwrap();
        results.push(
            s.eval_str("sum(unlist(lapply(1:5, function(x) x) |> futurize()))")
                .unwrap()
                .as_f64()
                .unwrap(),
        );
    }
    assert!(results.iter().all(|&v| v == 15.0), "{results:?}");
}
