//! The future.tests analog (paper §2.1 footnote 2): every backend must
//! be compliant with the Future API. One conformance suite, run against
//! all six backends — including `cluster_tcp`, whose workers are real
//! processes dialing back over localhost sockets.

mod common;

use common::{within, worker_env};
use futurize::backend::Backend;
use futurize::prelude::*;

const PLANS: &[&str] = &[
    "sequential",
    "multicore, workers = 2",
    "multisession, workers = 2",
    "cluster, workers = c(\"n1\", \"n2\"), latency_ms = 0.1",
    "cluster_tcp, workers = 2",
    "future.batchtools::batchtools_slurm, workers = 2, poll_ms = 2",
];

fn for_each_plan(f: impl Fn(&mut Session, &str)) {
    worker_env();
    for plan in PLANS {
        let mut s = Session::new();
        s.eval_str(&format!("plan({plan})")).unwrap();
        f(&mut s, plan);
    }
}

#[test]
fn values_match_sequential_reference() {
    worker_env();
    let reference = Session::new()
        .eval_str("unlist(lapply(1:12, function(x) x^2 + 1))")
        .unwrap();
    for_each_plan(|s, plan| {
        let v = s
            .eval_str("unlist(lapply(1:12, function(x) x^2 + 1) |> futurize())")
            .unwrap_or_else(|e| panic!("{plan}: {e}"));
        assert_eq!(v, reference, "{plan}");
    });
}

#[test]
fn globals_are_exported_by_value() {
    for_each_plan(|s, plan| {
        let v = s
            .eval_str(
                "a <- 10\nf <- function(x) x + a\nr <- lapply(1:3, f) |> futurize()\na <- 999\nunlist(r)",
            )
            .unwrap_or_else(|e| panic!("{plan}: {e}"));
        assert_eq!(v.as_dbl_vec().unwrap(), vec![11.0, 12.0, 13.0], "{plan}");
    });
}

#[test]
fn errors_preserve_the_original_condition() {
    // The paper's §1 critique: mclapply/parLapply lose the error object.
    for_each_plan(|s, plan| {
        let v = s
            .eval_str(
                "r <- tryCatch(\n  lapply(1:4, function(x) if (x == 3) stop(\"original message\") else x) |> futurize(),\n  error = function(e) conditionMessage(e))\nr",
            )
            .unwrap_or_else(|e| panic!("{plan}: {e}"));
        assert_eq!(v.as_str().unwrap(), "original message", "{plan}");
    });
}

#[test]
fn stdout_and_messages_relay() {
    for_each_plan(|s, plan| {
        let (r, out) = s.eval_captured(
            "ys <- lapply(1:2, function(x) { cat(\"o\", x, \"\")\nmessage(\"m\", x)\nx }) |> futurize()\nunlist(ys)",
        );
        let v = r.unwrap_or_else(|e| panic!("{plan}: {e}"));
        assert_eq!(v.as_dbl_vec().unwrap(), vec![1.0, 2.0], "{plan}");
        assert!(out.contains("o 1"), "{plan}: stdout lost: {out:?}");
        assert!(out.contains("m1"), "{plan}: message lost: {out:?}");
    });
}

#[test]
fn warnings_relay_and_are_suppressible() {
    for_each_plan(|s, plan| {
        let (_, noisy) = s.eval_captured(
            "ys <- lapply(1:2, function(x) { warning(\"w\", x)\nx }) |> futurize()",
        );
        assert!(noisy.contains("w1"), "{plan}: warning lost: {noisy:?}");
        let (_, quiet) = s.eval_captured(
            "ys <- lapply(1:2, function(x) { warning(\"w\", x)\nx }) |> suppressWarnings() |> futurize()",
        );
        assert!(!quiet.contains("w1"), "{plan}: suppression failed: {quiet:?}");
    });
}

#[test]
fn seed_true_reproducible_per_backend() {
    worker_env();
    let reference = {
        let mut s = Session::new();
        s.eval_str("futureSeed(31)").unwrap();
        s.eval_str("unlist(lapply(1:8, function(x) rnorm(1)) |> futurize(seed = TRUE))")
            .unwrap()
    };
    for_each_plan(|s, plan| {
        s.eval_str("futureSeed(31)").unwrap();
        let v = s
            .eval_str("unlist(lapply(1:8, function(x) rnorm(1)) |> futurize(seed = TRUE))")
            .unwrap_or_else(|e| panic!("{plan}: {e}"));
        assert_eq!(v, reference, "{plan}: RNG streams must be backend-invariant");
    });
}

#[test]
fn chunking_options_respected() {
    for_each_plan(|s, plan| {
        for opts in ["chunk_size = 1", "chunk_size = 5", "scheduling = Inf", "scheduling = 2"] {
            let v = s
                .eval_str(&format!(
                    "unlist(lapply(1:10, function(x) x * 2) |> futurize({opts}))"
                ))
                .unwrap_or_else(|e| panic!("{plan}/{opts}: {e}"));
            assert_eq!(
                v.as_dbl_vec().unwrap(),
                (1..=10).map(|x| (x * 2) as f64).collect::<Vec<_>>(),
                "{plan}/{opts}"
            );
        }
    });
}

#[test]
fn low_level_future_api_works_everywhere() {
    for_each_plan(|s, plan| {
        let v = s
            .eval_str("f1 <- future(1 + 1)\nf2 <- future(2 + 2)\nvalue(f1) + value(f2)")
            .unwrap_or_else(|e| panic!("{plan}: {e}"));
        assert_eq!(v.as_f64().unwrap(), 6.0, "{plan}");
    });
}

#[test]
fn empty_input_yields_empty_result() {
    for_each_plan(|s, plan| {
        let v = s
            .eval_str("r <- lapply(NULL, function(x) x) |> futurize()\nlength(r)")
            .unwrap_or_else(|e| panic!("{plan}: {e}"));
        assert_eq!(v.as_f64().unwrap(), 0.0, "{plan}");
    });
}

// ---------------------------------------------------------------------------
// Backend-level conformance for the streaming dispatch protocol:
// cancellation and shared-context registration, exercised on the raw
// Backend trait for every plan kind.
// ---------------------------------------------------------------------------

fn raw_backends() -> Vec<(String, Box<dyn Backend>)> {
    worker_env();
    PLANS
        .iter()
        .map(|plan| {
            let name = plan.split(',').next().unwrap().trim().to_string();
            let workers = Some(2);
            let spec = futurize::backend::PlanSpec::from_name(
                &name,
                workers,
                vec![],
                Some(0.1),
                Some(2.0),
            )
            .unwrap();
            (name, futurize::backend::instantiate(&spec, 1).unwrap())
        })
        .collect()
}

fn sleep_task(id: u64, seconds: f64) -> futurize::future_core::TaskPayload {
    futurize::future_core::TaskPayload {
        id,
        kind: futurize::future_core::TaskKind::Expr {
            expr: futurize::rlite::parse_expr(&format!("Sys.sleep({seconds})")).unwrap(),
            globals: vec![],
            nesting: Default::default(),
        },
        time_scale: 1.0,
        capture_stdout: true,
    }
}

#[test]
fn cancelled_tasks_never_execute() {
    for (name, mut b) in raw_backends() {
        let workers = b.workers();
        // Occupy every worker with a slow task...
        for id in 1..=workers as u64 {
            b.submit(sleep_task(id, 0.5)).unwrap();
        }
        // ...give the backend time to hand them out...
        std::thread::sleep(std::time::Duration::from_millis(150));
        // ...then queue quick tasks behind them and cancel the queue.
        let queued = 6u64;
        for id in 0..queued {
            b.submit(sleep_task(100 + id, 0.0)).unwrap();
        }
        let cancelled = b.cancel_queued();
        if name == "sequential" {
            // Sequential runs inline at submit; nothing is ever queued.
            assert!(cancelled.is_empty(), "{name}: {cancelled:?}");
        } else {
            assert!(!cancelled.is_empty(), "{name}: expected cancellable queued tasks");
            // Only queued (never started) tasks may be cancelled.
            for id in &cancelled {
                assert!(*id >= 100, "{name}: cancelled a running task: {id}");
            }
        }
        let expect_done = workers + queued as usize - cancelled.len();
        let mut done = 0;
        while done < expect_done {
            if let futurize::backend::BackendEvent::Done(_) = b.next_event().unwrap() {
                done += 1;
            }
        }
        // A cancelled task must never execute → no further events, ever.
        std::thread::sleep(std::time::Duration::from_millis(150));
        let extra = b.try_next_event().unwrap();
        assert!(extra.is_none(), "{name}: cancelled task produced an event: {extra:?}");
    }
}

#[test]
fn cluster_sim_polling_never_blocks_the_driver() {
    use std::time::{Duration, Instant};
    worker_env();
    // 40 ms one-way latency: big enough that a sleep hiding inside the
    // poll path is unmistakable against the 20 ms per-poll bound.
    let spec = futurize::backend::PlanSpec::from_name(
        "cluster",
        None,
        vec!["n1".into(), "n2".into()],
        Some(40.0),
        None,
    )
    .unwrap();
    let mut b = futurize::backend::instantiate(&spec, 1).unwrap();
    b.submit(sleep_task(1, 0.0)).unwrap();
    // Poll the result in. The Done spends 40 ms in simulated flight
    // after the worker finishes, yet every individual poll must return
    // immediately — the driver stays free to do other work, which is
    // the whole point of `resolved()`-style polling.
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        assert!(Instant::now() < deadline, "cluster_sim task never resolved");
        let t0 = Instant::now();
        let ev = b.try_next_event().unwrap();
        let took = t0.elapsed();
        assert!(
            took < Duration::from_millis(20),
            "try_next_event blocked the driver for {took:?} (latency model must \
             stamp arrival deadlines, not sleep on the caller)"
        );
        match ev {
            Some(futurize::backend::BackendEvent::Done(_)) => break,
            Some(_) => {}
            None => std::thread::sleep(Duration::from_millis(1)),
        }
    }
}

#[test]
fn cluster_tcp_attach_mode_accepts_external_workers() {
    use std::process::{Command, Stdio};
    worker_env();
    // Parent listens on an explicit localhost port; the worker is
    // launched *by the test* and dials in — exactly the deployment
    // shape of `plan(cluster, workers = "tcp://host:port")` with remote
    // machines, minus the machines.
    let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = probe.local_addr().unwrap().to_string();
    drop(probe);
    let backend_thread = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            futurize::backend::cluster_tcp::ClusterTcpBackend::new(1, &addr, "attach", 500.0)
        })
    };
    // Wait for the backend thread to bind before the single-shot
    // connect below. The probe connection is closed immediately, so the
    // acceptor sees a clean EOF and moves on.
    let t0 = std::time::Instant::now();
    loop {
        match std::net::TcpStream::connect(&addr) {
            Ok(s) => {
                drop(s);
                break;
            }
            Err(_) if t0.elapsed() < std::time::Duration::from_secs(20) => {
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
            Err(e) => panic!("attach listener never came up: {e}"),
        }
    }
    let bin = std::env::var("FUTURIZE_WORKER_BIN").unwrap();
    let mut worker = Command::new(&bin)
        .args(["worker", "--connect", &addr])
        .stdin(Stdio::null())
        .spawn()
        .expect("cannot launch external worker");
    let mut b = match backend_thread.join() {
        Ok(Ok(b)) => b,
        Ok(Err(e)) => {
            let _ = worker.kill();
            panic!("attach-mode construction failed: {e}");
        }
        Err(e) => std::panic::resume_unwind(e),
    };
    assert_eq!(b.workers(), 1);
    b.submit(sleep_task(7, 0.0)).unwrap();
    let done = loop {
        match b.next_event().unwrap() {
            futurize::backend::BackendEvent::Done(o) => break o,
            _ => {}
        }
    };
    assert_eq!(done.id, 7);
    // Dropping the backend closes the socket; the external worker exits
    // on its own (it is not the parent's child in attach mode).
    drop(b);
    let _ = worker.wait();
}

#[test]
fn contexts_register_resolve_and_drop() {
    use futurize::future_core::{ContextBody, TaskContext, TaskKind, TaskPayload};
    for (name, mut b) in raw_backends() {
        let f_wire = {
            let mut s = Session::new();
            s.eval_str("__f <- function(x) x + 40").unwrap();
            let f = futurize::rlite::env::lookup(&s.interp.global, "__f").unwrap();
            futurize::rlite::serialize::to_wire(&f).unwrap()
        };
        b.register_context(std::sync::Arc::new(TaskContext {
            id: 1,
            body: ContextBody::Map { f: f_wire, extra: vec![] },
            globals: vec![],
            cached_globals: vec![],
            nesting: Default::default(),
            kernel: None,
            reduce: None,
        }))
        .unwrap();
        b.submit(TaskPayload {
            id: 1,
            kind: TaskKind::MapSlice {
                ctx: 1,
                items: vec![futurize::rlite::serialize::WireVal::Dbl(vec![2.0], None)].into(),
                seeds: None,
            },
            time_scale: 0.0,
            capture_stdout: true,
        })
        .unwrap();
        loop {
            match b.next_event().unwrap() {
                futurize::backend::BackendEvent::Done(o) => {
                    let vals = o.values.unwrap_or_else(|e| panic!("{name}: {}", e.message));
                    match &vals[0] {
                        futurize::rlite::serialize::WireVal::Dbl(v, _) => {
                            assert_eq!(v[0], 42.0, "{name}")
                        }
                        other => panic!("{name}: {other:?}"),
                    }
                    break;
                }
                futurize::backend::BackendEvent::Progress { .. } => {}
                other => panic!("{name}: unexpected event: {other:?}"),
            }
        }
        b.drop_context(1).unwrap();
    }
}

#[test]
fn stop_on_error_cancels_remaining_work() {
    worker_env();
    // 24 one-per-element chunks of 0.2 scaled-units each on 2 workers:
    // running everything costs ≥ 2.4 time-units; failing fast on the
    // first element must come in far below that.
    let mut s = Session::with_config(SessionConfig { time_scale: 0.25 });
    s.eval_str("plan(multicore, workers = 2)").unwrap();
    let t0 = std::time::Instant::now();
    let err = s
        .eval_str(
            "lapply(1:24, function(x) { if (x == 1) stop(\"fail fast\")\nSys.sleep(0.2)\nx }) \
             |> futurize(scheduling = Inf, stop_on_error = TRUE)",
        )
        .unwrap_err();
    let elapsed = t0.elapsed().as_secs_f64();
    assert!(err.contains("fail fast"), "{err}");
    // Full execution would need ≥ 0.6s wall (24 × 0.05s / 2 workers);
    // fail-fast drains only the in-flight window.
    assert!(
        elapsed < 0.45,
        "stop_on_error did not cancel queued chunks: took {elapsed:.2}s"
    );
    // Without stop_on_error the same input runs to completion and
    // reports the same (first-in-input-order) error.
    let err2 = s
        .eval_str(
            "lapply(1:24, function(x) { if (x == 1) stop(\"fail fast\")\nSys.sleep(0.01)\nx }) \
             |> futurize(scheduling = Inf)",
        )
        .unwrap_err();
    assert!(err2.contains("fail fast"), "{err2}");
}

// ---------------------------------------------------------------------------
// Kill-worker conformance: a worker that dies mid-map must never hang
// the session — every process backend either recovers (retries ≥ 1) or
// raises a FutureError-style condition, within a bounded wall clock.
// ---------------------------------------------------------------------------

const PROCESS_PLANS: &[&str] = &[
    "multisession, workers = 2",
    "cluster, workers = c(\"n1\", \"n2\"), latency_ms = 0.1",
    "cluster_tcp, workers = 2",
    "future.batchtools::batchtools_slurm, workers = 2, poll_ms = 2",
];

#[test]
fn killed_worker_raises_future_error_not_hang() {
    // Default retries = 0: fail fast with a FutureError naming the lost
    // worker, exactly like R future's unreliable-worker behaviour.
    for &plan in PROCESS_PLANS {
        let plan_owned = plan.to_string();
        let err = within(60, plan, move || {
            worker_env();
            let mut s = Session::new();
            s.eval_str(&format!("plan({plan_owned})")).unwrap();
            s.eval_str(
                "lapply(1:6, function(x) { if (x == 4) futurize_test_exit()\nx }) \
                 |> futurize(chunk_size = 1)",
            )
            .unwrap_err()
        });
        assert!(err.contains("terminated unexpectedly"), "{plan}: {err}");
        assert!(err.contains("worker"), "{plan}: should name the worker: {err}");
    }
}

#[test]
fn killed_worker_recovers_with_retries() {
    // retries = 1 with exactly one induced crash: the lost chunk is
    // resubmitted and the map call still returns correct input-ordered
    // results.
    for (k, &plan) in PROCESS_PLANS.iter().enumerate() {
        let marker = std::env::temp_dir()
            .join(format!("futurize-kill-once-{}-{k}", std::process::id()));
        let _ = std::fs::remove_file(&marker);
        let plan_owned = plan.to_string();
        let marker_str = marker.display().to_string();
        let got = within(60, plan, move || {
            worker_env();
            let mut s = Session::new();
            s.eval_str(&format!("plan({plan_owned})")).unwrap();
            let (r, _out) = s.eval_captured(&format!(
                "unlist(lapply(1:6, function(x) {{ \
                 if (x == 4) futurize_test_exit_once(\"{marker_str}\")\nx * 3 }}) \
                 |> futurize(chunk_size = 1, retries = 1))"
            ));
            r.unwrap().as_dbl_vec().unwrap()
        });
        let _ = std::fs::remove_file(&marker);
        assert_eq!(got, (1..=6).map(|x| (x * 3) as f64).collect::<Vec<_>>(), "{plan}");
    }
}

#[test]
fn plan_switching_mid_session() {
    worker_env();
    let mut s = Session::new();
    let mut results = Vec::new();
    for plan in PLANS {
        s.eval_str(&format!("plan({plan})")).unwrap();
        results.push(
            s.eval_str("sum(unlist(lapply(1:5, function(x) x) |> futurize()))")
                .unwrap()
                .as_f64()
                .unwrap(),
        );
    }
    assert!(results.iter().all(|&v| v == 15.0), "{results:?}");
}
