//! Golden-diagnostic suite for the parallel-safety analyzer (ISSUE 8):
//! each detector's accept/reject matrix across the six API families,
//! `lint = "error"` raising at freeze time (zero workers spawned),
//! relay dedup (one warning per map call, not per chunk), the
//! `FUTURIZE_LINT` env overrides, the fusion/reduce rejection report,
//! and the `record_result` wire metric on the simulated HPC backends.
//!
//! Every test serializes on one mutex: `FUTURIZE_LINT` and
//! `FUTURIZE_NO_FUSION` are process env vars, and the worker-spawn /
//! fusion / wire counters are process globals, so concurrent tests
//! would race all of them.

mod common;

use std::sync::{Mutex, MutexGuard, OnceLock};

use common::{within, worker_env};
use futurize::backend::multisession;
use futurize::prelude::*;
use futurize::rlite::diag;
use futurize::transpile::{analysis, fusion};
use futurize::wire::stats;

fn serial() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    // A panicked test must not wedge the rest of the suite.
    LOCK.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
}

/// Run `f` with `FUTURIZE_LINT` pinned (or removed, for the default
/// `warn` mode), restoring the ambient value afterwards.
fn with_lint<T>(val: Option<&str>, f: impl FnOnce() -> T) -> T {
    let ambient = std::env::var(diag::LINT_ENV).ok();
    match val {
        Some(v) => std::env::set_var(diag::LINT_ENV, v),
        None => std::env::remove_var(diag::LINT_ENV),
    }
    let r = f();
    match ambient {
        Some(v) => std::env::set_var(diag::LINT_ENV, v),
        None => std::env::remove_var(diag::LINT_ENV),
    }
    r
}

fn run_captured(plan: &str, fixture: &str, prog: &str) -> (Result<RVal, String>, String) {
    let mut s = Session::new();
    s.eval_str(plan).unwrap_or_else(|e| panic!("{plan}: {e}"));
    s.eval_str("futureSeed(99)").unwrap();
    if !fixture.is_empty() {
        s.eval_str(fixture).unwrap_or_else(|e| panic!("{fixture}: {e}"));
    }
    s.eval_captured(prog)
}

const MC2: &str = "plan(multicore, workers = 2)";
const MS2: &str = "plan(multisession, workers = 2)";

/// The classic loop-carried accumulator: writes `total` into the
/// calling frame *and* reads it, so element i depends on element i-1.
const DIRTY_FIXTURE: &str = "
    xs <- c(1, 2, 3, 4)
    total <- 0
    f <- function(x) {
      total <<- total + x
      x * 2
    }
";
const DIRTY_MAP: &str = "unlist(lapply(xs, f) |> futurize())";

#[test]
fn dirty_body_under_default_warn_runs_and_relays_exactly_once() {
    let _g = serial();
    with_lint(None, || {
        // workers = 2 means two chunks; a per-chunk relay would print
        // FZ001 twice. The contract is once per map call.
        let (r, out) = run_captured(MC2, DIRTY_FIXTURE, DIRTY_MAP);
        let v = r.expect("warn mode must still execute the map");
        assert_eq!(v.as_dbl_vec().unwrap(), vec![2.0, 4.0, 6.0, 8.0]);
        assert_eq!(out.matches("FZ001").count(), 1, "FZ001 must relay exactly once:\n{out}");
        assert!(out.contains("futurize lint: FZ001"), "warning must carry the code:\n{out}");
        assert!(out.contains("fix:"), "warning must carry the fix hint:\n{out}");

        // The relayed condition is classed, so user handlers can
        // target it without string matching.
        let prog = "tryCatch(lapply(xs, f) |> futurize(), \
                    FuturizeLintWarning = function(w) \"classed\")";
        let (r, _) = run_captured(MC2, DIRTY_FIXTURE, prog);
        assert_eq!(r.unwrap().as_str().unwrap(), "classed");
    });
}

#[test]
fn lint_error_raises_at_freeze_time_before_any_worker_spawns() {
    let _g = serial();
    worker_env();
    with_lint(None, || {
        let spawned_before = multisession::workers_spawned();
        let (r, _) =
            run_captured(MS2, DIRTY_FIXTURE, "lapply(xs, f) |> futurize(lint = \"error\")");
        let e = r.expect_err("lint = \"error\" must raise on the dirty body");
        assert!(e.contains("FZ001"), "error must carry the code: {e}");
        assert!(e.contains("fix:"), "error must carry the fix hint: {e}");
        assert_eq!(
            multisession::workers_spawned(),
            spawned_before,
            "the analyzer raised after a worker was spawned"
        );

        // The raised condition is classed (FuturizeLintError, also a
        // FutureError) so tryCatch can target it.
        let prog = "tryCatch(lapply(xs, f) |> futurize(lint = \"error\"), \
                    FuturizeLintError = function(e) \"caught\")";
        let (r, _) = run_captured(MS2, DIRTY_FIXTURE, prog);
        assert_eq!(r.unwrap().as_str().unwrap(), "caught");

        // Sanity: the spawn counter is live — a clean map on the same
        // plan does spawn workers.
        let (r, _) = within(60, "clean multisession map", || {
            run_captured(
                MS2,
                "xs <- c(1, 2, 3, 4)",
                "unlist(lapply(xs, function(x) x * 2) |> futurize())",
            )
        });
        r.unwrap();
        assert!(multisession::workers_spawned() > spawned_before, "spawn counter never ticked");
    });
}

#[test]
fn futurize_lint_env_overrides_kill_switch_and_promotion() {
    let _g = serial();
    // FUTURIZE_LINT=off silences even explicit lint = "warn".
    with_lint(Some("off"), || {
        let (r, out) =
            run_captured(MC2, DIRTY_FIXTURE, "unlist(lapply(xs, f) |> futurize(lint = \"warn\"))");
        r.unwrap();
        assert!(!out.contains("FZ001"), "kill switch leaked a diagnostic:\n{out}");
    });
    // FUTURIZE_LINT=error promotes the default warn mode to a raise.
    with_lint(Some("error"), || {
        let (r, _) = run_captured(MC2, DIRTY_FIXTURE, DIRTY_MAP);
        let e = r.expect_err("env promotion must raise");
        assert!(e.contains("FZ001"), "{e}");
    });
    // An invalid env value falls back to the per-call mode.
    with_lint(Some("banana"), || {
        let (r, out) = run_captured(MC2, DIRTY_FIXTURE, DIRTY_MAP);
        r.unwrap();
        assert_eq!(out.matches("FZ001").count(), 1, "{out}");
    });
}

/// FZ001 fires once — and exactly once — through every Table-1 API
/// family surface, not just base lapply.
#[test]
fn fz001_relays_once_across_all_six_api_families() {
    let _g = serial();
    let families: &[(&str, &str)] = &[
        ("base", "unlist(lapply(xs, f) |> futurize())"),
        ("purrr", "map_dbl(xs, f) |> futurize()"),
        (
            "foreach",
            "unlist((foreach(x = xs, .combine = c) %dofuture% { total <<- total + x; x * 2 }))",
        ),
        ("future.apply", "future_sapply(xs, f)"),
        ("furrr", "future_map_dbl(xs, f)"),
        ("BiocParallel", "unlist(bplapply(xs, f) |> futurize())"),
    ];
    with_lint(None, || {
        for (family, prog) in families {
            let (r, out) = run_captured(MC2, DIRTY_FIXTURE, prog);
            let v = r.unwrap_or_else(|e| panic!("{family}: {e}"));
            assert_eq!(v.as_dbl_vec().unwrap(), vec![2.0, 4.0, 6.0, 8.0], "{family}");
            assert_eq!(out.matches("FZ001").count(), 1, "{family}: relay count\n{out}");
        }
    });
}

#[test]
fn fz002_flags_unseeded_rng_and_accepts_seed_true() {
    let _g = serial();
    with_lint(None, || {
        let fixture = "xs <- c(1, 2, 3, 4)";
        let (r, out) = run_captured(
            MC2,
            fixture,
            "unlist(lapply(xs, function(x) rnorm(1) + x) |> futurize())",
        );
        r.unwrap();
        assert_eq!(out.matches("FZ002").count(), 1, "{out}");
        assert!(out.contains("seed = TRUE"), "hint must name the fix:\n{out}");

        let (r, out) = run_captured(
            MC2,
            fixture,
            "unlist(lapply(xs, function(x) rnorm(1) + x) |> futurize(seed = TRUE))",
        );
        r.unwrap();
        assert!(!out.contains("FZ002"), "seeded map must be clean:\n{out}");
    });
}

#[test]
fn fz003_warns_at_the_parent_before_the_worker_fails() {
    let _g = serial();
    with_lint(None, || {
        let (r, out) = run_captured(
            MC2,
            "xs <- c(1, 2, 3, 4)",
            "unlist(lapply(xs, function(x) x * missing_scale) |> futurize())",
        );
        // The map still fails worker-side (same as without the
        // analyzer) — but the diagnostic landed first, at the parent.
        let e = r.expect_err("unresolvable global must still fail at runtime");
        assert!(e.contains("missing_scale"), "{e}");
        assert_eq!(out.matches("FZ003").count(), 1, "{out}");
        assert!(out.contains("missing_scale"), "diagnostic must name the symbol:\n{out}");
    });
}

#[test]
fn fz005_flags_user_combine_under_the_assoc_contract() {
    let _g = serial();
    with_lint(None, || {
        let fixture = "
            xs <- c(3, 1, 4, 1)
            mycomb <- function(a, b) a - b
        ";
        let prog = "(foreach(x = xs, .combine = mycomb, \
                    .options.future = list(reduce = \"assoc\")) %dofuture% { x * 2 })";
        let (r, out) = run_captured(MC2, fixture, prog);
        // ((6 - 2) - 8) - 2: the non-associative fold still runs in
        // submission order — the diagnostic is advisory under warn.
        assert_eq!(r.unwrap().as_f64().unwrap(), -6.0);
        assert_eq!(out.matches("FZ005").count(), 1, "{out}");

        // Without the assoc contract the same combine is silent: the
        // full-result path replays it pairwise in order, so there is
        // nothing order-dependent to flag.
        let prog = "(foreach(x = xs, .combine = mycomb) %dofuture% { x * 2 })";
        let (r, out) = run_captured(MC2, fixture, prog);
        assert_eq!(r.unwrap().as_f64().unwrap(), -6.0);
        assert!(!out.contains("FZ005"), "{out}");
    });
}

#[test]
fn clean_body_under_error_mode_executes_normally() {
    let _g = serial();
    with_lint(None, || {
        let (r, out) = run_captured(
            MC2,
            "xs <- c(1, 2, 3, 4)\nscale <- 3",
            "unlist(lapply(xs, function(x) x * scale) |> futurize(lint = \"error\"))",
        );
        assert_eq!(r.unwrap().as_dbl_vec().unwrap(), vec![3.0, 6.0, 9.0, 12.0]);
        assert!(!out.contains("FZ0"), "clean body produced a diagnostic:\n{out}");
    });
}

fn reason(pairs: &[(&'static str, u64)], label: &str) -> u64 {
    pairs.iter().find(|(l, _)| *l == label).map(|(_, n)| *n).unwrap_or(0)
}

/// Satellite (b): the per-reason rejection counters behind
/// `fusion_report()` tick for kernel env-mutation and shadowed-reduce.
#[test]
fn fusion_report_labels_env_mutation_and_shadowed_reduce() {
    let _g = serial();
    with_lint(None, || {
        let ambient = std::env::var(fusion::NO_FUSION_ENV).ok();
        std::env::remove_var(fusion::NO_FUSION_ENV);

        let before = fusion_report();
        // `<<-` in the body: outside the kernel catalog, reason
        // "env-mutation".
        let (r, _) = run_captured(MC2, DIRTY_FIXTURE, DIRTY_MAP);
        r.unwrap();
        let after = fusion_report();
        assert!(
            reason(&after.kernel_rejections, "env-mutation")
                > reason(&before.kernel_rejections, "env-mutation"),
            "env-mutation rejection must be counted:\n{}",
            after.render()
        );

        // A user rebinding of `sum` keeps the full-result path, reason
        // "shadowed" — and the shadowing binding sees all 5 elements.
        let before = fusion_report();
        let (r, _) = run_captured(
            MC2,
            "sum <- function(v) length(v)",
            "sum(sapply(1:5, function(x) x)) |> futurize()",
        );
        assert_eq!(r.unwrap().as_f64().unwrap(), 5.0);
        let after = fusion_report();
        assert!(
            reason(&after.reduce_rejections, "shadowed")
                > reason(&before.reduce_rejections, "shadowed"),
            "shadowed reduce rejection must be counted:\n{}",
            after.render()
        );

        match ambient {
            Some(v) => std::env::set_var(fusion::NO_FUSION_ENV, v),
            None => std::env::remove_var(fusion::NO_FUSION_ENV),
        }
    });
}

/// Satellite (a): the `record_result` wire metric now ticks on the
/// batchtools job path and on cluster_sim (via its wrapped
/// multisession reader threads), not just raw multisession.
#[test]
fn hpc_sim_backends_record_result_bytes() {
    let _g = serial();
    worker_env();
    with_lint(None, || {
        for plan in [
            "plan(future.batchtools::batchtools_slurm, workers = 2, poll_ms = 2)",
            "plan(cluster, workers = c(\"n1\", \"n2\"), latency_ms = 0.1)",
        ] {
            stats::reset();
            let plan_owned = plan.to_string();
            let (r, _) = within(60, plan, move || {
                run_captured(
                    &plan_owned,
                    "xs <- c(1, 2, 3, 4)",
                    "unlist(lapply(xs, function(x) x * 2) |> futurize())",
                )
            });
            assert_eq!(r.unwrap().as_dbl_vec().unwrap(), vec![2.0, 4.0, 6.0, 8.0], "{plan}");
            assert!(stats::result_bytes() > 0, "{plan}: result bytes metric never ticked");
        }
    });
}

/// The CLI fixtures under examples/r/ stay golden: the dirty script
/// carries FZ001/FZ002/FZ003 and the clean script has no findings.
/// (CI additionally asserts the exit codes of `futurize-rs lint`.)
#[test]
fn cli_fixtures_lint_as_expected() {
    let _g = serial();
    with_lint(None, || {
        let dirty = std::fs::read_to_string("../examples/r/lint_dirty.R").unwrap();
        let findings = analysis::lint_source(&dirty).expect("dirty fixture parses");
        assert!(!findings.is_empty(), "dirty fixture produced no findings");
        let codes: Vec<&str> = findings
            .iter()
            .flat_map(|f| f.diags.iter().map(|d| d.code.as_str()))
            .collect();
        for want in ["FZ001", "FZ002", "FZ003"] {
            assert!(codes.contains(&want), "dirty fixture must flag {want}, got {codes:?}");
        }

        let clean = std::fs::read_to_string("../examples/r/lint_clean.R").unwrap();
        let findings = analysis::lint_source(&clean).expect("clean fixture parses");
        assert!(findings.is_empty(), "clean fixture flagged: {findings:?}");
    });
}
