//! Copy-on-modify semantics under the COW value representation
//! (ISSUE 4 satellite): sharing payload buffers behind `Rc` must be
//! *unobservable* from R code — callee writes never leak into callers,
//! `<-` into a shared binding copies, and snapshot surfaces
//! (`env::flatten`, globals export) keep the values they saw.

use futurize::prelude::*;
use futurize::rlite::env;
use futurize::rlite::eval::Interp;

fn run(src: &str) -> RVal {
    Interp::new().eval_program(src).unwrap_or_else(|e| panic!("{src}: {e:?}"))
}

#[test]
fn callee_mutation_invisible_in_caller() {
    let v = run(
        "x <- c(1, 2, 3)\n\
         f <- function(v) { v[1] <- 99\nv[1] }\n\
         r <- f(x)\n\
         c(r, x[1])",
    );
    assert_eq!(v.as_dbl_vec().unwrap(), vec![99.0, 1.0]);
}

#[test]
fn assignment_into_shared_binding_copies() {
    let v = run(
        "x <- c(1, 2, 3)\n\
         y <- x\n\
         y[2] <- 9\n\
         c(x[2], y[2])",
    );
    assert_eq!(v.as_dbl_vec().unwrap(), vec![2.0, 9.0]);
}

#[test]
fn loop_mutation_of_alias_keeps_original() {
    let v = run(
        "x <- c(0, 0, 0, 0)\n\
         y <- x\n\
         for (i in 1:4) y[i] <- i\n\
         c(sum(x), sum(y))",
    );
    assert_eq!(v.as_dbl_vec().unwrap(), vec![0.0, 10.0]);
}

#[test]
fn lookup_shares_buffer_until_write() {
    // White-box: two reads of the same binding alias one buffer (O(1)
    // lookups); an R-level write detaches the writer only.
    let mut i = Interp::new();
    i.eval_program("x <- c(1, 2, 3, 4)").unwrap();
    let a = env::lookup(&i.global, "x").unwrap();
    let b = env::lookup(&i.global, "x").unwrap();
    match (&a, &b) {
        (RVal::Dbl(a), RVal::Dbl(b)) => assert!(a.shares_buffer(b), "reads must not copy"),
        other => panic!("{other:?}"),
    }
    i.eval_program("x[1] <- 7").unwrap();
    let c = env::lookup(&i.global, "x").unwrap();
    assert_eq!(a.as_dbl_vec().unwrap(), vec![1.0, 2.0, 3.0, 4.0], "snapshot must survive");
    assert_eq!(c.as_dbl_vec().unwrap(), vec![7.0, 2.0, 3.0, 4.0]);
}

#[test]
fn super_assign_through_shared_value_is_isolated() {
    let v = run(
        "acc <- c(1, 1)\n\
         snap <- acc\n\
         bump <- function() acc[1] <<- acc[1] + 1\n\
         bump()\nbump()\n\
         c(acc[1], snap[1])",
    );
    assert_eq!(v.as_dbl_vec().unwrap(), vec![3.0, 1.0]);
}

#[test]
fn env_flatten_snapshots_values() {
    let mut i = Interp::new();
    i.eval_program("z <- c(5, 6)").unwrap();
    let flat = env::flatten(&i.global);
    let z0 = flat.iter().find(|(k, _)| k == "z").unwrap().1.clone();
    i.eval_program("z[1] <- -1").unwrap();
    assert_eq!(z0.as_dbl_vec().unwrap(), vec![5.0, 6.0], "flatten snapshot must not follow writes");
}

#[test]
fn globals_export_snapshots_before_later_mutation() {
    // future() exports `a` by value at submit time; mutating `a` before
    // value() must not change the worker's view (paper §2.4 semantics,
    // preserved under buffer sharing).
    let v = run(
        "plan(multicore, workers = 2)\n\
         a <- c(1, 2)\n\
         f <- future(sum(a))\n\
         a <- c(50, 50)\n\
         value(f)",
    );
    assert_eq!(v.as_f64().unwrap(), 3.0);
}

#[test]
fn futurized_map_with_mutating_callee_matches_sequential() {
    let mut s = Session::new();
    s.eval_str("xs <- 1:6\nfcn <- function(x) { x[1] <- x[1] * 10\nx[1] }").unwrap();
    let seq = s.eval_str("unlist(lapply(xs, fcn))").unwrap();
    s.eval_str("plan(multicore, workers = 3)").unwrap();
    let fut = s.eval_str("unlist(lapply(xs, fcn) |> futurize())").unwrap();
    assert_eq!(seq, fut);
    assert_eq!(seq.as_dbl_vec().unwrap(), vec![10.0, 20.0, 30.0, 40.0, 50.0, 60.0]);
}

#[test]
fn eapply_snapshot_not_affected_by_callee_writes() {
    let v = run(
        "e <- new.env()\n\
         e$v <- c(2, 4)\n\
         r <- eapply(e, function(col) { col[1] <- 0\nsum(col) })\n\
         c(r[[1]], e$v[1])",
    );
    assert_eq!(v.as_dbl_vec().unwrap(), vec![4.0, 2.0]);
}

#[test]
fn interned_ast_roundtrips_through_binary_wire() {
    // Symbols/params serialize as identifier text: a closure shipped to
    // a "worker" decodes to the same behavior.
    let mut i = Interp::new();
    i.eval_program("k <- 3\nf <- function(x, n = 2) x^n + k").unwrap();
    let f = env::lookup(&i.global, "f").unwrap();
    let w = futurize::rlite::serialize::to_wire(&f).unwrap();
    let bytes = futurize::wire::bin::to_bytes(&w).unwrap();
    let back: futurize::rlite::serialize::WireVal =
        futurize::wire::bin::from_bytes(&bytes).unwrap();
    let mut worker = Interp::new();
    let g = futurize::rlite::serialize::from_wire_owned(back, &worker.global);
    env::define(&worker.global.clone(), "g", g);
    assert_eq!(worker.eval_program("g(2)").unwrap(), RVal::scalar_dbl(7.0));
    assert_eq!(worker.eval_program("g(2, n = 3)").unwrap(), RVal::scalar_dbl(11.0));
}

#[test]
fn deparse_is_stable_under_interning() {
    for src in [
        "lapply(xs, function(x) x + 1)",
        "for (i in 1:10) s <- s + i",
        "foreach(x = xs) %do% { f(x) }",
    ] {
        let e = futurize::rlite::parse_expr(src).unwrap();
        let text = futurize::rlite::deparse::deparse(&e);
        let e2 = futurize::rlite::parse_expr(&text).unwrap();
        assert_eq!(e, e2, "{src}");
    }
}
