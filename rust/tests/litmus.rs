//! Exp X3 — the paper's §5.2 "parallelization litmus test" as property
//! tests: `rev(lapply(rev(xs), fcn))` must equal `lapply(xs, fcn)`, and
//! futurized results must be invariant to worker count, chunking, and
//! element order. Randomized inputs are generated with the crate's own
//! MRG32k3a (proptest is not available offline).

use futurize::prelude::*;
use futurize::rng::RngStream;

fn worker_env() {
    std::env::set_var(
        futurize::backend::worker::WORKER_BIN_ENV,
        env!("CARGO_BIN_EXE_futurize-rs"),
    );
}

fn random_vector(g: &mut RngStream, n: usize) -> String {
    let vals: Vec<String> =
        (0..n).map(|_| format!("{:.4}", g.next_f64() * 200.0 - 100.0)).collect();
    format!("c({})", vals.join(", "))
}

/// Pure functions to map with (no RNG — order-independent).
const FCNS: &[&str] = &[
    "function(x) x^2",
    "function(x) sqrt(abs(x)) + 1",
    "function(x) if (x > 0) x else -x",
    "function(x) sum(hlo_chunk_map(c(x, x)))",
];

#[test]
fn litmus_reverse_invariance_sequential() {
    let mut g = RngStream::from_seed(101);
    for trial in 0..20 {
        let n = 1 + g.next_below(12);
        let xs = random_vector(&mut g, n);
        let f = FCNS[g.next_below(FCNS.len())];
        let mut s = Session::new();
        s.eval_str(&format!("xs <- {xs}\nfcn <- {f}")).unwrap();
        let a = s.eval_str("unlist(lapply(xs, fcn))").unwrap();
        let b = s.eval_str("unlist(rev(lapply(rev(xs), fcn)))").unwrap();
        assert_eq!(a, b, "trial {trial}: fcn={f} xs={xs}");
    }
}

#[test]
fn litmus_futurized_equals_sequential() {
    let mut g = RngStream::from_seed(202);
    for trial in 0..20 {
        let n = 1 + g.next_below(16);
        let xs = random_vector(&mut g, n);
        let f = FCNS[g.next_below(FCNS.len())];
        let workers = 1 + g.next_below(4);
        let mut s = Session::new();
        s.eval_str(&format!("xs <- {xs}\nfcn <- {f}")).unwrap();
        let seq = s.eval_str("unlist(lapply(xs, fcn))").unwrap();
        s.eval_str(&format!("plan(multicore, workers = {workers})")).unwrap();
        let fut = s.eval_str("unlist(lapply(xs, fcn) |> futurize())").unwrap();
        assert_eq!(seq, fut, "trial {trial}: workers={workers} fcn={f}");
    }
}

#[test]
fn litmus_chunking_invariance() {
    let mut g = RngStream::from_seed(303);
    for trial in 0..15 {
        let n = 2 + g.next_below(20);
        let xs = random_vector(&mut g, n);
        let chunk = 1 + g.next_below(n);
        let mut s = Session::new();
        s.eval_str(&format!("plan(multicore, workers = 3)\nxs <- {xs}")).unwrap();
        let a = s
            .eval_str("unlist(lapply(xs, function(x) x * 3) |> futurize())")
            .unwrap();
        let b = s
            .eval_str(&format!(
                "unlist(lapply(xs, function(x) x * 3) |> futurize(chunk_size = {chunk}))"
            ))
            .unwrap();
        assert_eq!(a, b, "trial {trial}: chunk_size={chunk} n={n}");
    }
}

#[test]
fn litmus_adaptive_dispatch_invariance() {
    // Guided (adaptive) chunking must not change values or order.
    let mut g = RngStream::from_seed(707);
    for trial in 0..10 {
        let n = 2 + g.next_below(24);
        let xs = random_vector(&mut g, n);
        let mut s = Session::new();
        s.eval_str(&format!("plan(multicore, workers = 3)\nxs <- {xs}")).unwrap();
        let a = s
            .eval_str("unlist(lapply(xs, function(x) x * 3) |> futurize())")
            .unwrap();
        let b = s
            .eval_str(
                "unlist(lapply(xs, function(x) x * 3) |> futurize(scheduling = \"adaptive\"))",
            )
            .unwrap();
        assert_eq!(a, b, "trial {trial}: n={n}");
    }
}

#[test]
fn litmus_rng_reverse_with_per_element_streams() {
    // With seed = TRUE the paper's exception disappears: element k gets
    // stream k regardless of processing order, so even *random* numbers
    // satisfy the reverse-invariance property elementwise.
    let mut s = Session::new();
    s.eval_str("plan(multicore, workers = 3)").unwrap();
    s.eval_str("futureSeed(99)").unwrap();
    let fwd = s
        .eval_str("unlist(lapply(1:8, function(x) rnorm(1)) |> futurize(seed = TRUE))")
        .unwrap();
    s.eval_str("futureSeed(99)").unwrap();
    let scrambled = s
        .eval_str(
            "unlist(lapply(1:8, function(x) rnorm(1)) |> futurize(seed = TRUE, scheduling = Inf))",
        )
        .unwrap();
    assert_eq!(fwd, scrambled);
}

#[test]
fn litmus_multisession_matches_multicore() {
    worker_env();
    let mut g = RngStream::from_seed(404);
    for _ in 0..5 {
        let n = 1 + g.next_below(10);
        let xs = random_vector(&mut g, n);
        let mut s = Session::new();
        s.eval_str(&format!("xs <- {xs}")).unwrap();
        s.eval_str("plan(multicore, workers = 2)").unwrap();
        let a = s.eval_str("unlist(lapply(xs, function(x) x / 3) |> futurize())").unwrap();
        s.eval_str("plan(multisession, workers = 2)").unwrap();
        let b = s.eval_str("unlist(lapply(xs, function(x) x / 3) |> futurize())").unwrap();
        assert_eq!(a, b);
    }
}

#[test]
fn scheduling_policy_properties() {
    // make_chunks: total coverage, contiguity, count bounds — swept over
    // random (n, workers, policy).
    use futurize::scheduling::{make_chunks, ChunkPolicy};
    let mut g = RngStream::from_seed(505);
    for _ in 0..500 {
        let n = g.next_below(200);
        let workers = 1 + g.next_below(16);
        let policy = match g.next_below(4) {
            0 => ChunkPolicy::Static {
                chunk_size: Some(1 + g.next_below(20)),
                scheduling: 1.0,
            },
            1 => ChunkPolicy::Static {
                chunk_size: None,
                scheduling: 0.25 + g.next_f64() * 8.0,
            },
            2 => ChunkPolicy::Static { chunk_size: None, scheduling: f64::INFINITY },
            _ => ChunkPolicy::Adaptive { min_chunk: 1 + g.next_below(5) },
        };
        let chunks = make_chunks(n, workers, &policy);
        let total: usize = chunks.iter().map(|(s, e)| e - s).sum();
        assert_eq!(total, n);
        for w in chunks.windows(2) {
            assert_eq!(w[0].1, w[1].0);
        }
        if n > 0 {
            assert!(!chunks.is_empty());
            assert!(chunks.len() <= n);
        }
    }
}

#[test]
fn wire_roundtrip_of_random_values() {
    // Serialization substrate property: to_wire/from_wire/JSON roundtrip
    // over randomized nested values built in rlite.
    let mut g = RngStream::from_seed(606);
    for _ in 0..30 {
        let n = 1 + g.next_below(6);
        let src = format!(
            "list(a = {}, b = \"s{}\", c = list(inner = {}), d = c({} > 0))",
            g.next_f64() * 10.0,
            g.next_below(100),
            random_vector(&mut g, n),
            g.next_f64() - 0.5,
        );
        let mut s = Session::new();
        let v = s.eval_str(&src).unwrap();
        let w = futurize::rlite::serialize::to_wire(&v).unwrap();
        let json = futurize::wire::to_string(&w).unwrap();
        let back: futurize::rlite::serialize::WireVal =
            futurize::wire::from_str(&json).unwrap();
        assert_eq!(w, back, "{src}");
        let env = futurize::rlite::env::Env::new_ref();
        let v2 = futurize::rlite::serialize::from_wire(&back, &env);
        assert_eq!(v, v2, "{src}");
    }
}
