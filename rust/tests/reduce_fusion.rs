//! Differential suite for worker-side reduction fusion (ISSUE 7):
//! every recognized reduce shape — `sum(<map>)`-style heads,
//! `Reduce(f, <map>)`, `foreach(.combine = ...)` — must produce results
//! identical to `plan(sequential)` on every backend (bit-identical for
//! exact-gate folds) while shipping O(workers) result bytes instead of
//! O(n). CI re-runs this file with `FUTURIZE_NO_FUSION=1`, under which
//! every test degenerates to the full-result path — still a valid
//! differential.
//!
//! Every test serializes on one mutex: the kill switch is a process
//! env var and the reduce/wire counters are process globals, so
//! concurrent tests would race both.

mod common;

use std::sync::{Mutex, MutexGuard, OnceLock};

use common::{within, worker_env};
use futurize::prelude::*;
use futurize::transpile::{fusion, reduce};
use futurize::wire::stats;

fn serial() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    // A panicked test must not wedge the rest of the suite.
    LOCK.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
}

/// Run `f` with fusion forced on or off, restoring the ambient state
/// (which CI may pin to off for the conformance leg) afterwards.
fn with_fusion<T>(on: bool, f: impl FnOnce() -> T) -> T {
    let ambient = std::env::var(fusion::NO_FUSION_ENV).ok();
    if on {
        std::env::remove_var(fusion::NO_FUSION_ENV);
    } else {
        std::env::set_var(fusion::NO_FUSION_ENV, "1");
    }
    let r = f();
    match ambient {
        Some(v) => std::env::set_var(fusion::NO_FUSION_ENV, v),
        None => std::env::remove_var(fusion::NO_FUSION_ENV),
    }
    r
}

/// Bit pattern of a numeric result — exactness is the contract under
/// test, so every comparison is on f64 bits, not tolerances.
fn bits(v: &RVal) -> Vec<u64> {
    v.as_dbl_vec().unwrap().iter().map(|x| x.to_bits()).collect()
}

fn run_with(plan: &str, fixture: &str, prog: &str, fuse: bool) -> RVal {
    with_fusion(fuse, || {
        let mut s = Session::new();
        s.eval_str(plan).unwrap_or_else(|e| panic!("{plan}: {e}"));
        s.eval_str("futureSeed(99)").unwrap();
        if !fixture.is_empty() {
            s.eval_str(fixture).unwrap_or_else(|e| panic!("{fixture}: {e}"));
        }
        s.eval_str(prog).unwrap_or_else(|e| panic!("{plan} / {prog}: {e}"))
    })
}

const PLANS: &[&str] = &[
    "plan(sequential)",
    "plan(multicore, workers = 2)",
    "plan(multisession, workers = 2)",
    "plan(cluster, workers = c(\"n1\", \"n2\"), latency_ms = 0.1)",
    "plan(future.batchtools::batchtools_slurm, workers = 2, poll_ms = 2)",
];

/// In-process plans, where the worker-side fold counters tick in *this*
/// process (process backends fold inside their worker processes).
const LOCAL_PLANS: &[&str] = &["plan(sequential)", "plan(multicore, workers = 2)"];

#[test]
fn head_form_reductions_bit_identical_on_every_backend() {
    let _g = serial();
    worker_env();
    let fixture = "xs <- 1:9";
    // Integral values: every head is exact-gate eligible, so fused and
    // full-result paths must agree to the bit (and in type).
    let progs = [
        "sum(sapply(xs, function(x) x * 3)) |> futurize()",
        "mean(sapply(xs, function(x) x * 3)) |> futurize()",
        "min(sapply(xs, function(x) x * 3)) |> futurize()",
        "max(unlist(lapply(xs, function(x) x * 3))) |> futurize()",
        "any(sapply(xs, function(x) x > 5)) |> futurize()",
        "all(sapply(xs, function(x) x > 0)) |> futurize()",
        "prod(sapply(xs, function(x) x)) |> futurize()",
    ];
    for prog in progs {
        for plan in PLANS {
            let fused = run_with(plan, fixture, prog, true);
            let full = run_with(plan, fixture, prog, false);
            assert_eq!(bits(&fused), bits(&full), "{plan} / {prog}: value bits diverge");
            assert_eq!(fused.class(), full.class(), "{plan} / {prog}: class diverges");
        }
    }
    // The fused runs above must actually have attached plans, and on
    // in-process plans the slices demonstrably folded worker-side.
    let attached_before = reduce::plans_attached();
    for plan in LOCAL_PLANS {
        let folded_before = reduce::slices_folded();
        run_with(plan, fixture, "sum(sapply(xs, function(x) x * 3)) |> futurize()", true);
        assert!(reduce::slices_folded() > folded_before, "{plan}: no slice folded");
    }
    assert!(reduce::plans_attached() > attached_before, "no reduce plan attached");
}

#[test]
fn direct_marker_form_reduces_on_future_apply_and_furrr() {
    let _g = serial();
    worker_env();
    // The runtime marker convention the transpiler emits, written by
    // hand — both API families must honor it.
    let progs = [
        ("sum(future_sapply(1:20, function(x) x + 1, future.reduce.op = \"sum\"))", 230.0),
        ("sum(furrr::future_map_dbl(1:8, function(x) x * 2, future.reduce.op = \"sum\"))", 72.0),
    ];
    for (prog, want) in progs {
        for fuse in [true, false] {
            let v = run_with("plan(multicore, workers = 2)", "", prog, fuse);
            assert_eq!(v.as_f64().unwrap(), want, "fuse={fuse}: {prog}");
        }
    }
}

#[test]
fn foreach_combines_bit_identical_on_every_backend() {
    let _g = serial();
    worker_env();
    let fixture = "xs <- c(3, 1, 4, 1, 5, 9, 2, 6)";
    // `.combine` ∈ {c, +, min} map onto worker-side folds; the default
    // (list) combine rides the full-result path and must be untouched.
    let cases = [
        "foreach(x = xs, .combine = c) %dofuture% { x * 2 + 1 }",
        "foreach(x = xs, .combine = `+`) %dofuture% { x * 2 + 1 }",
        "foreach(x = xs, .combine = min) %dofuture% { x * 2 + 1 }",
        "foreach(x = xs, .combine = max) %dofuture% { x - 7 }",
        "foreach(x = xs) %dofuture% { x + 1 }",
    ];
    for prog in cases {
        let reference = {
            let seq = prog.replace("%dofuture%", "%do%");
            run_with("plan(sequential)", fixture, &seq, true)
        };
        for plan in PLANS {
            for fuse in [true, false] {
                let par = run_with(plan, fixture, prog, fuse);
                assert_eq!(par, reference, "{plan} / fuse={fuse} / {prog}");
            }
        }
    }
    // Combine mapping must engage: a recognized `.combine` attaches a
    // plan and folds on in-process workers.
    let attached_before = reduce::plans_attached();
    let folded_before = reduce::slices_folded();
    run_with(
        "plan(multicore, workers = 2)",
        fixture,
        "foreach(x = xs, .combine = `+`) %dofuture% { x * 2 + 1 }",
        true,
    );
    assert!(reduce::plans_attached() > attached_before, ".combine = + must attach a plan");
    assert!(reduce::slices_folded() > folded_before, ".combine = + slices must fold");
}

/// Acceptance: a fused `sum` over 1e5 elements ships O(workers) result
/// bytes on `plan(multisession)`; the same call with fusion disabled
/// ships all 1e5 values back.
#[test]
fn fused_sum_ships_o_workers_result_bytes() {
    let _g = serial();
    worker_env();
    let fixture = "xs <- 1:100000";
    let prog = "sum(future_sapply(xs, function(x) x + 1, future.reduce.op = \"sum\"))";
    let want = 5_000_150_000.0;
    let mut measured = [0u64; 2];
    for (k, fuse) in [true, false].into_iter().enumerate() {
        measured[k] = with_fusion(fuse, || {
            let mut s = Session::new();
            s.eval_str("plan(multisession, workers = 2)").unwrap();
            s.eval_str(fixture).unwrap();
            // Reset after setup so only this map's Done frames count.
            stats::reset();
            let v = s.eval_str(prog).unwrap_or_else(|e| panic!("fuse={fuse}: {e}"));
            assert_eq!(v.as_f64().unwrap(), want, "fuse={fuse}");
            stats::result_bytes()
        });
    }
    let [fused, full] = measured;
    assert!(fused < 2_000, "fused sum must ship O(workers) result bytes, shipped {fused}");
    assert!(full > 100_000, "full-result path must ship O(n) result bytes, shipped {full}");
}

#[test]
fn fused_reduction_survives_worker_loss_without_double_count() {
    let _g = serial();
    // retries = 1 with exactly one induced crash: the lost chunk is
    // re-executed, and its partial must enter the combine tree exactly
    // once — 63, not 63 + a replayed chunk.
    let marker =
        std::env::temp_dir().join(format!("futurize-reduce-kill-{}", std::process::id()));
    let _ = std::fs::remove_file(&marker);
    let marker_str = marker.display().to_string();
    let got = within(60, "reduce+retries", move || {
        with_fusion(true, || {
            worker_env();
            let mut s = Session::new();
            s.eval_str("plan(multisession, workers = 2)").unwrap();
            s.eval_str(&format!(
                "sum(sapply(1:6, function(x) {{ \
                 if (x == 4) futurize_test_exit_once(\"{marker_str}\")\nx * 3 }})) \
                 |> futurize(chunk_size = 1, retries = 1)"
            ))
            .unwrap()
            .as_f64()
            .unwrap()
        })
    });
    let _ = std::fs::remove_file(&marker);
    assert_eq!(got, 63.0, "retried chunk double-counted or lost its partial");
}

#[test]
fn stop_on_error_with_reduction_surfaces_the_error() {
    let _g = serial();
    worker_env();
    let prog = "sum(sapply(1:12, function(x) { if (x == 5) stop(\"boom\")\nx })) \
                |> futurize(chunk_size = 1, stop_on_error = TRUE)";
    for plan in ["plan(multicore, workers = 2)", "plan(multisession, workers = 2)"] {
        for fuse in [true, false] {
            let err = with_fusion(fuse, || {
                let mut s = Session::new();
                s.eval_str(plan).unwrap();
                s.eval_str(prog).unwrap_err()
            });
            assert!(err.contains("boom"), "{plan} / fuse={fuse}: {err}");
        }
    }
}

#[test]
fn depth2_nested_fused_reduction_matches_sequential() {
    let _g = serial();
    worker_env();
    // The inner futurized reduce runs on the worker-side inner backend
    // at depth 2; integral values keep both levels exact.
    let prog = "unlist(lapply(1:3, function(x) \
        sum(future_sapply(1:40, function(y) y * 2 + x, future.reduce.op = \"sum\"))) \
        |> futurize())";
    let reference = run_with("plan(sequential)", "", prog, true);
    assert_eq!(reference.as_dbl_vec().unwrap(), vec![1680.0, 1720.0, 1760.0]);
    for plan in
        ["plan(list(multicore(2), multicore(2)))", "plan(list(multisession(2), multicore(2)))"]
    {
        for fuse in [true, false] {
            let v = run_with(plan, "", prog, fuse);
            assert_eq!(bits(&v), bits(&reference), "{plan} / fuse={fuse}: depth-2 diverges");
        }
    }
}

#[test]
fn exact_gate_rejects_float_sums_and_assoc_opts_in() {
    let _g = serial();
    let fixture = "xs <- (1:4000) * 0.1";
    let prog = "sum(sapply(xs, function(x) x * 0.5)) |> futurize()";
    let seqv = run_with("plan(sequential)", fixture, prog, false);
    // Default (exact) mode: non-integral values fail the gate on every
    // slice, the chunks ship full results, and the parent folds them in
    // order — bit-identical to sequential, observably via the fallback
    // counter.
    let fallback_before = reduce::slices_fallback();
    let exact = run_with("plan(multicore, workers = 2)", fixture, prog, true);
    assert_eq!(bits(&exact), bits(&seqv), "gate fallback must stay bit-exact");
    assert!(reduce::slices_fallback() > fallback_before, "float sum must trip the gate");
    // `reduce = "assoc"` accepts reassociated folding: slices fold, and
    // the result agrees within the documented summation-error bound.
    let folded_before = reduce::slices_folded();
    let assoc = run_with(
        "plan(multicore, workers = 2)",
        fixture,
        "sum(sapply(xs, function(x) x * 0.5)) |> futurize(reduce = \"assoc\")",
        true,
    );
    assert!(reduce::slices_folded() > folded_before, "assoc slices must fold");
    let (a, s) = (assoc.as_f64().unwrap(), seqv.as_f64().unwrap());
    assert!((a - s).abs() <= 1e-9 * s.abs(), "assoc sum too far off: {a} vs {s}");
}

#[test]
fn reduce_form_folds_and_unwraps_through_outer_reduce() {
    let _g = serial();
    worker_env();
    let fixture = "xs <- c(7, 3, 9, 5)";
    let prog = "Reduce(min, lapply(xs, function(x) x * 2)) |> futurize()";
    for plan in PLANS {
        for fuse in [true, false] {
            let v = run_with(plan, fixture, prog, fuse);
            assert_eq!(v.as_f64().unwrap(), 6.0, "{plan} / fuse={fuse}");
        }
    }
}

#[test]
fn length_head_is_exact_for_nonsimplifying_and_simplifying_maps() {
    let _g = serial();
    worker_env();
    let fixture = "xs <- 1:6";
    // lapply keeps a 6-element list; sapply flattens the uniform
    // length-2 columns to 12. The fused dummy must reproduce both.
    let progs = [
        "length(lapply(xs, function(x) c(x, x))) |> futurize()",
        "length(sapply(xs, function(x) c(x, x))) |> futurize()",
        "length(map(xs, function(x) c(x, x))) |> futurize()",
    ];
    for prog in progs {
        for plan in ["plan(multicore, workers = 2)", "plan(multisession, workers = 2)"] {
            let fused = run_with(plan, fixture, prog, true);
            let full = run_with(plan, fixture, prog, false);
            assert_eq!(bits(&fused), bits(&full), "{plan} / {prog}");
        }
    }
}

#[test]
fn shadowed_outer_symbol_disables_the_fold() {
    let _g = serial();
    // A user rebinding of the kept outer symbol must receive the full
    // result, never a pre-folded aggregate: `length(v)` distinguishes
    // the 5-element vector from a folded scalar.
    let v = run_with(
        "plan(multicore, workers = 2)",
        "sum <- function(v) length(v)",
        "sum(sapply(1:5, function(x) x)) |> futurize()",
        true,
    );
    assert_eq!(v.as_f64().unwrap(), 5.0, "shadowed sum() saw a folded aggregate");
    // Same for a shadowed `Reduce` in the fold form: it must see the
    // full list, not the fused length-1 wrapper.
    let v = run_with(
        "plan(multicore, workers = 2)",
        "Reduce <- function(f, v) length(v)",
        "Reduce(min, lapply(1:4, function(x) x)) |> futurize()",
        true,
    );
    assert_eq!(v.as_f64().unwrap(), 4.0, "shadowed Reduce() saw the fused wrapper");
}

#[test]
fn kill_switch_suppresses_plan_attach_entirely() {
    let _g = serial();
    let attached_before = reduce::plans_attached();
    let v = run_with(
        "plan(multicore, workers = 2)",
        "",
        "sum(sapply(1:6, function(x) x)) |> futurize()",
        false,
    );
    assert_eq!(v.as_f64().unwrap(), 21.0);
    assert_eq!(reduce::plans_attached(), attached_before, "kill switch leaked a plan");
}
