//! Supervision + retry regression suite: worker crashes must surface as
//! recovery (`retries ≥ 1`) or a `FutureError`-style condition — never
//! as a hang. Covers the dispatch core deterministically (a scriptable
//! lossy backend), the real process backends (kill/desync hooks), the
//! batchtools failure exit paths, and bounded teardown.

mod common;

use std::collections::VecDeque;
use std::sync::Arc;

use common::{within, worker_env};
use futurize::backend::multicore::MulticoreBackend;
use futurize::backend::multisession::MultisessionBackend;
use futurize::backend::{Backend, BackendEvent};
use futurize::future_core::driver::{map_elements, MapOptions};
use futurize::future_core::{TaskContext, TaskKind, TaskPayload};
use futurize::prelude::*;
use futurize::rlite::eval::Signal;

// ---------------------------------------------------------------------------
// Deterministic dispatch-core coverage: a backend that "loses" the
// first N submitted tasks (they never run; a WorkerLost is emitted
// instead of their Done), exactly like a worker dying at pickup.
// ---------------------------------------------------------------------------

struct LoseFirstBackend {
    inner: Box<dyn Backend>,
    losses_left: usize,
    pending_loss: VecDeque<u64>,
}

impl LoseFirstBackend {
    fn new(inner: Box<dyn Backend>, losses: usize) -> Self {
        LoseFirstBackend { inner, losses_left: losses, pending_loss: VecDeque::new() }
    }
}

impl Backend for LoseFirstBackend {
    fn name(&self) -> &'static str {
        "lose-first"
    }

    fn workers(&self) -> usize {
        self.inner.workers()
    }

    fn register_context(&mut self, ctx: Arc<TaskContext>) -> Result<(), String> {
        self.inner.register_context(ctx)
    }

    fn drop_context(&mut self, ctx_id: u64) -> Result<(), String> {
        self.inner.drop_context(ctx_id)
    }

    fn submit(&mut self, task: TaskPayload) -> Result<(), String> {
        if self.losses_left > 0 {
            self.losses_left -= 1;
            self.pending_loss.push_back(task.id);
            return Ok(());
        }
        self.inner.submit(task)
    }

    fn next_event(&mut self) -> Result<BackendEvent, String> {
        if let Some(id) = self.pending_loss.pop_front() {
            return Ok(BackendEvent::WorkerLost { worker: 0, task: Some(id) });
        }
        self.inner.next_event()
    }

    fn try_next_event(&mut self) -> Result<Option<BackendEvent>, String> {
        if let Some(id) = self.pending_loss.pop_front() {
            return Ok(Some(BackendEvent::WorkerLost { worker: 0, task: Some(id) }));
        }
        self.inner.try_next_event()
    }

    fn cancel_queued(&mut self) -> Vec<u64> {
        self.inner.cancel_queued()
    }
}

fn lossy_session(losses: usize) -> Session {
    let mut s = Session::new();
    s.interp.session.install_backend(Box::new(LoseFirstBackend::new(
        Box::new(MulticoreBackend::new(2)),
        losses,
    )));
    s
}

fn closure(s: &mut Session, src: &str) -> RVal {
    s.eval_str(&format!("__f <- {src}")).unwrap();
    futurize::rlite::env::lookup(&s.interp.global, "__f").unwrap()
}

#[test]
fn lost_chunk_is_resubmitted_under_retry_budget() {
    let mut s = lossy_session(1);
    let f = closure(&mut s, "function(x) x * 2");
    let items: Vec<RVal> = (1..=8).map(|k| RVal::scalar_dbl(k as f64)).collect();
    let genv = s.interp.global.clone();
    let opts = MapOptions { retries: 1, ..Default::default() };
    let (out, log) = s.interp.capture_stdout(move |i| {
        let genv2 = genv.clone();
        map_elements(i, &genv2, items, &f, vec![], &opts)
    });
    let out = out.unwrap();
    let got: Vec<f64> = out.iter().map(|v| v.as_f64().unwrap()).collect();
    assert_eq!(got, (1..=8).map(|k| (k * 2) as f64).collect::<Vec<_>>());
    // The resubmission is announced, not silent.
    assert!(log.contains("resubmitting"), "expected a retry warning, got: {log:?}");
}

#[test]
fn lost_chunk_without_retries_raises_future_error() {
    let mut s = lossy_session(1);
    let f = closure(&mut s, "function(x) x * 2");
    let items: Vec<RVal> = (1..=8).map(|k| RVal::scalar_dbl(k as f64)).collect();
    let genv = s.interp.global.clone();
    let err = map_elements(
        &mut s.interp,
        &genv,
        items,
        &f,
        vec![],
        &MapOptions::default(),
    )
    .unwrap_err();
    match err {
        Signal::Error(c) => {
            assert!(c.inherits("FutureError"), "{:?}", c.classes);
            assert!(c.message.contains("terminated unexpectedly"), "{}", c.message);
            assert!(c.message.contains("worker 0"), "{}", c.message);
        }
        other => panic!("{other:?}"),
    }
    // The session stays usable: the next map call on the same backend
    // runs normally.
    let g = closure(&mut s, "function(x) x + 1");
    let items: Vec<RVal> = (1..=4).map(|k| RVal::scalar_dbl(k as f64)).collect();
    let out =
        map_elements(&mut s.interp, &genv, items, &g, vec![], &MapOptions::default()).unwrap();
    assert_eq!(out.len(), 4);
}

#[test]
fn lost_low_level_future_raises_future_error() {
    let mut s = lossy_session(1);
    let err = s.eval_str("f <- future(21 * 2)\nvalue(f)").unwrap_err();
    assert!(err.contains("terminated unexpectedly"), "{err}");
    assert!(err.contains("worker"), "{err}");
    // resolved() reports the lost future as resolved (its error is
    // ready to collect), so poll loops terminate.
    let mut s = lossy_session(1);
    let v = s.eval_str("f <- future(1)\nresolved(f)").unwrap();
    assert_eq!(v, RVal::scalar_bool(true));
}

// ---------------------------------------------------------------------------
// Real process backends.
// ---------------------------------------------------------------------------

#[test]
fn multisession_drop_with_wedged_worker_is_bounded() {
    // A worker stuck mid-task never reads the Shutdown message; Drop
    // must fall back to kill() after a short grace period instead of
    // wait()ing forever.
    let elapsed = within(30, "multisession drop", || {
        worker_env();
        let mut b = MultisessionBackend::new(1).unwrap();
        b.submit(TaskPayload {
            id: 1,
            kind: TaskKind::Expr {
                expr: futurize::rlite::parse_expr("Sys.sleep(600)").unwrap(),
                globals: vec![],
                nesting: Default::default(),
            },
            time_scale: 1.0,
            capture_stdout: true,
        })
        .unwrap();
        // Let the worker pick the task up and wedge.
        std::thread::sleep(std::time::Duration::from_millis(300));
        let t0 = std::time::Instant::now();
        drop(b);
        t0.elapsed().as_secs_f64()
    });
    assert!(elapsed < 10.0, "drop took {elapsed:.1}s — grace period not enforced");
}

#[test]
fn batchtools_corrupt_job_file_is_an_error_outcome() {
    // An undecodable job file must produce a Done-with-error (and clean
    // up its claimed file), not a silent drop that hangs the dispatch
    // loop forever.
    let (msg, leftovers) = within(20, "batchtools corrupt job", || {
        let mut b =
            futurize::backend::batchtools_sim::BatchtoolsSimBackend::new(1, 2.0).unwrap();
        let jobs = b.spool_dir().join("jobs");
        let tmp = jobs.join("0000000000000042.tmp");
        let fin = jobs.join("0000000000000042.job");
        std::fs::write(&tmp, b"this is not a wire frame").unwrap();
        std::fs::rename(&tmp, &fin).unwrap();
        let msg = loop {
            match b.next_event().unwrap() {
                BackendEvent::Done(o) => {
                    assert_eq!(o.id, 42);
                    break o.values.unwrap_err().message;
                }
                BackendEvent::Progress { .. } => {}
                other => panic!("unexpected event: {other:?}"),
            }
        };
        // Give the filesystem a beat, then check nothing leaked into
        // running/.
        std::thread::sleep(std::time::Duration::from_millis(50));
        let leftovers = std::fs::read_dir(b.spool_dir().join("running"))
            .map(|rd| rd.count())
            .unwrap_or(0);
        (msg, leftovers)
    });
    assert!(msg.contains("decode"), "{msg}");
    assert_eq!(leftovers, 0, "failed job leaked its claimed file");
}

#[test]
fn protocol_desync_is_treated_as_worker_failure() {
    // Garbage injected into the middle of the worker protocol stream
    // must route through supervision (worker replaced, task reported
    // lost) instead of leaving the reader on a misaligned stream.
    let err = within(60, "multisession desync", || {
        worker_env();
        let mut s = Session::new();
        s.eval_str("plan(multisession, workers = 2)").unwrap();
        s.eval_str(
            "lapply(1:4, function(x) { if (x == 2) futurize_test_desync()\nx }) \
             |> futurize(chunk_size = 1)",
        )
        .unwrap_err()
    });
    assert!(err.contains("terminated unexpectedly"), "{err}");
}

#[test]
fn cluster_tcp_heartbeat_timeout_is_detected_mid_task() {
    // A TCP worker whose *connection* goes silent — no frames, no
    // heartbeats — must be reaped by the heartbeat deadline even though
    // the socket is still technically open. The test hook suppresses
    // the worker's heartbeat thread, so from the parent's side the
    // worker looks exactly like one on the far side of a dead network
    // link; the long-running task means no Done will save it either.
    std::env::set_var(futurize::backend::worker::NO_HEARTBEAT_ENV, "1");
    let (elapsed, event_ok) = within(30, "cluster_tcp heartbeat", || {
        worker_env();
        let mut b =
            futurize::backend::cluster_tcp::ClusterTcpBackend::new(1, "", "", 150.0).unwrap();
        b.submit(TaskPayload {
            id: 1,
            kind: TaskKind::Expr {
                expr: futurize::rlite::parse_expr("Sys.sleep(20)").unwrap(),
                globals: vec![],
                nesting: Default::default(),
            },
            time_scale: 1.0,
            capture_stdout: true,
        })
        .unwrap();
        let t0 = std::time::Instant::now();
        let ev = b.next_event().unwrap();
        let elapsed = t0.elapsed().as_secs_f64();
        let ok = matches!(ev, BackendEvent::WorkerLost { task: Some(1), .. });
        (elapsed, ok)
    });
    std::env::remove_var(futurize::backend::worker::NO_HEARTBEAT_ENV);
    assert!(event_ok, "expected WorkerLost for the silent worker's task");
    // Deadline is 2.5 × 150 ms; allow generous CI slack but stay far
    // below the 20 s task — proving the reap came from the heartbeat
    // model, not from task completion or socket close.
    assert!(
        elapsed < 10.0,
        "heartbeat timeout took {elapsed:.1}s — silent connection was not reaped"
    );
}

#[test]
fn cluster_tcp_runs_nested_stack_bit_identically() {
    // Depth-2 plan stack over the socket transport: the inherited inner
    // level travels inside RegisterContext frames exactly as it does
    // over stdio, so a TCP worker's nested map runs on its own inner
    // multicore pool — and seeded draws stay bit-identical to the
    // single-process reference.
    let reference: Vec<f64> = {
        let mut s = Session::new();
        s.eval_str("futureSeed(41)").unwrap();
        s.eval_str(
            "unlist(lapply(1:4, function(x) \
             sum(future_sapply(1:3, function(y) rnorm(1) * 0.001 + y * x, \
             future.seed = TRUE))) |> futurize(seed = TRUE, chunk_size = 1))",
        )
        .unwrap()
        .as_dbl_vec()
        .unwrap()
    };
    let got = within(90, "cluster_tcp nested stack", move || {
        worker_env();
        let mut s = Session::new();
        // heartbeat_ms = 0 keeps this test independent of the
        // NO_HEARTBEAT test hook, which a concurrently running test in
        // this process may have toggled in the shared environment.
        s.eval_str("plan(list(cluster_tcp(2, heartbeat_ms = 0), multicore(2)))").unwrap();
        s.eval_str("futureSeed(41)").unwrap();
        s.eval_str(
            "unlist(lapply(1:4, function(x) \
             sum(future_sapply(1:3, function(y) rnorm(1) * 0.001 + y * x, \
             future.seed = TRUE))) |> futurize(seed = TRUE, chunk_size = 1))",
        )
        .unwrap()
        .as_dbl_vec()
        .unwrap()
    });
    assert_eq!(got, reference, "nested TCP map drew different numbers");
}

#[test]
fn retry_preserves_seed_invariance_across_resubmit() {
    // seed = TRUE results must be identical whether or not a worker
    // crash forced a chunk to be resubmitted: per-element L'Ecuyer
    // streams travel with the chunk, so the replay draws the same
    // numbers.
    let reference: Vec<f64> = {
        let mut s = Session::new();
        s.eval_str("futureSeed(77)").unwrap();
        s.eval_str("unlist(lapply(1:8, function(x) rnorm(1)) |> futurize(seed = TRUE))")
            .unwrap()
            .as_dbl_vec()
            .unwrap()
    };
    let marker =
        std::env::temp_dir().join(format!("futurize-seed-kill-{}", std::process::id()));
    let _ = std::fs::remove_file(&marker);
    let marker_str = marker.display().to_string();
    let got = within(60, "multisession seeded retry", move || {
        worker_env();
        let mut s = Session::new();
        s.eval_str("plan(multisession, workers = 2)").unwrap();
        s.eval_str("futureSeed(77)").unwrap();
        let (r, _out) = s.eval_captured(&format!(
            "unlist(lapply(1:8, function(x) {{ \
             if (x == 5) futurize_test_exit_once(\"{marker_str}\")\nrnorm(1) }}) \
             |> futurize(seed = TRUE, chunk_size = 1, retries = 1))"
        ));
        r.unwrap().as_dbl_vec().unwrap()
    });
    let _ = std::fs::remove_file(&marker);
    assert_eq!(got, reference, "resubmitted chunk drew different random numbers");
}

#[test]
fn killed_outer_worker_replays_inherited_stack_on_respawn() {
    // Plan-stack supervision (ISSUE 5): kill an outer multisession
    // worker mid-nested-map. The replacement must receive the replayed
    // RegisterContext *including the inherited inner stack*, so the
    // retried chunk (retries = 1) recovers AND still runs its nested
    // map on the 2-worker inner multicore backend — observable both as
    // bit-identical seeded results and as inner_workers = 2 on every
    // trace event, the retried chunk's included.
    let reference: Vec<f64> = {
        let mut s = Session::new();
        s.eval_str("futureSeed(31)").unwrap();
        s.eval_str(
            "unlist(lapply(1:4, function(x) \
             sum(future_sapply(1:3, function(y) rnorm(1) * 0.001 + y * x, \
             future.seed = TRUE))) |> futurize(seed = TRUE, chunk_size = 1))",
        )
        .unwrap()
        .as_dbl_vec()
        .unwrap()
    };
    let marker =
        std::env::temp_dir().join(format!("futurize-nested-kill-{}", std::process::id()));
    let _ = std::fs::remove_file(&marker);
    let marker_str = marker.display().to_string();
    let (got, out, all_inner_parallel) = within(90, "nested supervision", move || {
        worker_env();
        let mut s = Session::new();
        s.eval_str("plan(list(multisession(2), multicore(2)))").unwrap();
        s.eval_str("futureSeed(31)").unwrap();
        let (r, out) = s.eval_captured(&format!(
            "unlist(lapply(1:4, function(x) {{ \
             if (x == 3) futurize_test_exit_once(\"{marker_str}\")\n\
             sum(future_sapply(1:3, function(y) rnorm(1) * 0.001 + y * x, \
             future.seed = TRUE)) }}) \
             |> futurize(seed = TRUE, chunk_size = 1, retries = 1))"
        ));
        let v = r.unwrap().as_dbl_vec().unwrap();
        let all_inner = s.last_trace().iter().all(|e| e.inner_workers == 2);
        (v, out, all_inner)
    });
    let _ = std::fs::remove_file(&marker);
    assert!(out.contains("resubmitting"), "expected a retry warning, got: {out:?}");
    assert_eq!(got, reference, "recovered nested map drew different numbers");
    assert!(
        all_inner_parallel,
        "the respawned worker must run its nested map on the inherited \
         2-worker inner backend (context replay lost the stack?)"
    );
}
