//! Helpers shared by the integration-test binaries.

// Each test binary compiles this module separately and uses only the
// helpers it needs; unused ones are not dead code in the workspace.
#![allow(dead_code)]

/// Integration tests run inside the libtest harness binary, which
/// cannot host workers; point process backends at the real CLI binary.
pub fn worker_env() {
    std::env::set_var(
        futurize::backend::worker::WORKER_BIN_ENV,
        env!("CARGO_BIN_EXE_futurize-rs"),
    );
}

/// Run `f` on a fresh thread under a hard wall-clock bound. A hang is
/// the exact bug the supervision suites exist to prevent, so exceeding
/// the bound fails the test immediately instead of stalling the
/// harness.
pub fn within<T: Send + 'static>(
    secs: u64,
    what: &str,
    f: impl FnOnce() -> T + Send + 'static,
) -> T {
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = tx.send(f());
    });
    match rx.recv_timeout(std::time::Duration::from_secs(secs)) {
        Ok(v) => v,
        Err(_) => panic!("{what}: no completion or error within {secs}s — hang"),
    }
}
