//! Differential fusion suite (ISSUE 6): every body the AOT recognizer
//! fuses must be **bit-identical** to the interpreted path — values,
//! seeded RNG draws, and condition/stdout relay — on every backend and
//! at nesting depths 1 and 2; bodies outside the catalog must fall back
//! to the interpreter, observably (trace counters). CI re-runs this
//! file with `FUTURIZE_WIRE_CODEC=json` and with `FUTURIZE_NO_FUSION=1`
//! (under which the whole suite degenerates to interpreter-vs-
//! interpreter — still a valid differential).
//!
//! Every test serializes on one mutex: the kill switch is a process
//! env var and the fusion trace counters are process globals, so
//! concurrent tests would race both.

mod common;

use std::sync::{Mutex, MutexGuard, OnceLock};

use common::worker_env;
use futurize::backend::multisession;
use futurize::prelude::*;
use futurize::transpile::fusion;

fn serial() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    // A panicked test must not wedge the rest of the suite.
    LOCK.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
}

/// Run `f` with fusion forced on or off, restoring the ambient state
/// (which CI may pin to off for the conformance leg) afterwards.
fn with_fusion<T>(on: bool, f: impl FnOnce() -> T) -> T {
    let ambient = std::env::var(fusion::NO_FUSION_ENV).ok();
    if on {
        std::env::remove_var(fusion::NO_FUSION_ENV);
    } else {
        std::env::set_var(fusion::NO_FUSION_ENV, "1");
    }
    let r = f();
    match ambient {
        Some(v) => std::env::set_var(fusion::NO_FUSION_ENV, v),
        None => std::env::remove_var(fusion::NO_FUSION_ENV),
    }
    r
}

/// Bit pattern of a numeric result — `assert_eq!` on `RVal` treats
/// NaN ≠ NaN, and the corner fixtures deliberately produce NaN/Inf.
fn bits(v: &RVal) -> Vec<u64> {
    v.as_dbl_vec().unwrap().iter().map(|x| x.to_bits()).collect()
}

fn run_with(plan: &str, fixture: &str, prog: &str, fuse: bool) -> (RVal, String) {
    with_fusion(fuse, || {
        let mut s = Session::new();
        s.eval_str(plan).unwrap_or_else(|e| panic!("{plan}: {e}"));
        s.eval_str("futureSeed(99)").unwrap();
        s.eval_str(fixture).unwrap();
        let (r, out) = s.eval_captured(prog);
        (r.unwrap_or_else(|e| panic!("{plan} / {prog}: {e}")), out)
    })
}

const PLANS: &[&str] = &[
    "plan(sequential)",
    "plan(multicore, workers = 2)",
    "plan(multisession, workers = 2)",
    "plan(cluster, workers = c(\"n1\", \"n2\"), latency_ms = 0.1)",
    "plan(future.batchtools::batchtools_slurm, workers = 2, poll_ms = 2)",
];

/// In-process plans, where the fusion slice counters tick in *this*
/// process (process backends fuse inside their workers).
const LOCAL_PLANS: &[&str] = &["plan(sequential)", "plan(multicore, workers = 2)"];

#[test]
fn elementwise_bit_identical_on_every_backend_with_nonfinite_corners() {
    let _g = serial();
    worker_env();
    // Inf, -Inf, NaN, and an overflow-on-square corner ride along: the
    // fused VM must reproduce the interpreter's f64 bits exactly.
    let fixture = "
        xs <- c(-1.5, 0, 0.5, 2, 1/0, -1/0, 0/0, 1e308, 3)
        f <- function(x) 3 * x * x + 2 * x + 1
    ";
    let prog = "future_sapply(xs, f)";
    for plan in PLANS {
        let recognized_before = fusion::contexts_recognized();
        let (fused, fused_out) = run_with(plan, fixture, prog, true);
        assert!(
            fusion::contexts_recognized() > recognized_before,
            "{plan}: recognizer must match the polynomial body"
        );
        let (interp, interp_out) = run_with(plan, fixture, prog, false);
        assert_eq!(bits(&fused), bits(&interp), "{plan}: value bits diverge");
        assert_eq!(fused_out, interp_out, "{plan}: relay text diverges");
    }
    // On in-process plans the fused slices demonstrably ran on the
    // kernel path, not just through an attached-but-ignored plan.
    for plan in LOCAL_PLANS {
        let fused_before = fusion::slices_fused();
        run_with(plan, fixture, prog, true);
        assert!(fusion::slices_fused() > fused_before, "{plan}: no slice fused");
    }
}

/// ISSUE 7 satellite: numeric-*vector* items run the ElemOp VM once per
/// component instead of falling back to the interpreter.
#[test]
fn elementwise_vector_items_bit_identical_on_every_backend() {
    let _g = serial();
    worker_env();
    fn list_bits(v: &RVal) -> Vec<Vec<u64>> {
        match v {
            RVal::List(l) => l.vals.iter().map(bits).collect(),
            other => vec![bits(other)],
        }
    }
    // Ragged lengths, non-finite corners, and a scalar straggler: the
    // per-component VM must reproduce the interpreter's f64 bits.
    let fixture = "
        xs <- list(c(-1.5, 0, 2.5), c(1/0, 0/0, -1/0), c(1e308, 3), 4)
        f <- function(x) 3 * x * x + 2 * x + 1
    ";
    let prog = "lapply(xs, f) |> futurize()";
    for plan in PLANS {
        let (fused, _) = run_with(plan, fixture, prog, true);
        let (interp, _) = run_with(plan, fixture, prog, false);
        assert_eq!(list_bits(&fused), list_bits(&interp), "{plan}: vector-item bits diverge");
    }
    for plan in LOCAL_PLANS {
        let fused_before = fusion::slices_fused();
        run_with(plan, fixture, prog, true);
        assert!(fusion::slices_fused() > fused_before, "{plan}: vector items did not fuse");
    }
}

#[test]
fn fused_bodies_leave_seeded_rng_streams_untouched() {
    let _g = serial();
    worker_env();
    // A fused map consumes no RNG; the seeded map after it must draw
    // the exact same stream as when everything runs interpreted.
    let fixture = "
        xs <- c(0.5, 1.5, 2.5, 3.5)
        f <- function(x) x * 2 + 1
    ";
    let prog = "
        a <- future_sapply(xs, f)
        b <- future_sapply(1:4, function(x) rnorm(1), future.seed = TRUE)
        c(a, b)
    ";
    for plan in PLANS {
        let (fused, _) = run_with(plan, fixture, prog, true);
        let (interp, _) = run_with(plan, fixture, prog, false);
        assert_eq!(bits(&fused), bits(&interp), "{plan}: RNG stream diverges");
    }
}

#[test]
fn depth2_nested_fused_inner_body_is_bit_identical() {
    let _g = serial();
    worker_env();
    // The inner closure captures `x` from the worker-side frame; the
    // recognizer fuses it inside the nested session at depth 2.
    let fixture = "nothing <- 0";
    let prog = "unlist(lapply(1:4, function(x) \
        sum(future_sapply(1:4, function(y) y * 2.0 + x))) |> futurize())";
    let reference = run_with("plan(sequential)", fixture, prog, false).0;
    for plan in
        ["plan(list(multicore(2), multicore(2)))", "plan(list(multisession(2), multicore(2)))"]
    {
        let fused_before = fusion::slices_fused();
        let (fused, _) = run_with(plan, fixture, prog, true);
        assert_eq!(bits(&fused), bits(&reference), "{plan}: depth-2 diverges");
        // Inner slices run on multicore worker threads of this process
        // for the first stack, so the fused counter must tick there.
        if plan == "plan(list(multicore(2), multicore(2)))" {
            assert!(fusion::slices_fused() > fused_before, "{plan}: inner body not fused");
        }
    }
}

#[test]
fn unmatched_bodies_run_interpreted_and_counters_say_so() {
    let _g = serial();
    let fixture = "
        xs <- c(1, 2, 3, 4)
        cnt <- 0
    ";
    // Env mutation, a condition, and a nested closure: all outside the
    // catalog, all must keep their interpreter semantics.
    let cases: &[(&str, &str)] = &[
        ("unlist(lapply(xs, function(x) { cnt <<- cnt + 1\nx * 2 }) |> futurize())", "env"),
        ("unlist(lapply(xs, function(x) { message(\"m\")\nx * 2 }) |> futurize())", "cond"),
        ("unlist(lapply(xs, function(x) (function(y) y + 1)(x)) |> futurize())", "closure"),
    ];
    let unmatched_before = fusion::contexts_unmatched();
    let fused_before = fusion::slices_fused();
    with_fusion(true, || {
        for (prog, tag) in cases {
            let mut s = Session::new();
            s.eval_str("plan(multicore, workers = 2)").unwrap();
            s.eval_str(fixture).unwrap();
            let (r, out) = s.eval_captured(prog);
            let v = r.unwrap_or_else(|e| panic!("{tag}: {e}"));
            match *tag {
                "closure" => assert_eq!(v.as_dbl_vec().unwrap(), vec![2.0, 3.0, 4.0, 5.0]),
                _ => assert_eq!(v.as_dbl_vec().unwrap(), vec![2.0, 4.0, 6.0, 8.0]),
            }
            if *tag == "cond" {
                assert_eq!(out.matches('m').count(), 4, "message relay must survive: {out:?}");
            }
        }
    });
    assert!(
        fusion::contexts_unmatched() >= unmatched_before + 3,
        "all three bodies must be rejected at freeze time"
    );
    assert_eq!(
        fusion::slices_fused(),
        fused_before,
        "no slice of an unmatched body may touch a kernel"
    );
}

#[test]
fn boot_statistic_bit_identical_including_dollar_form_and_zero_denominator() {
    let _g = serial();
    worker_env();
    let fixture = "
        x <- c(120, 150, 90, 200, 75, 60, 110, 95)
        u <- c(100, 140, 80, 180, 70, 55, 100, 90)
        d <- list(x = x, u = u)
        ws <- lapply(1:7, function(i) if (i == 7) c(0, 0, 0, 0, 0, 0, 0, 0) \
          else c(i, i * 0.5, 1, 2, i * 0.25, 1, 0.5, i))
        stat <- function(w) sum(x * w) / sum(u * w)
        stat_d <- function(w) sum(d$x * w) / sum(d$u * w)
    ";
    for prog in [
        "unlist(lapply(ws, stat) |> futurize())",
        "unlist(lapply(ws, stat_d) |> futurize())",
    ] {
        for plan in PLANS {
            let (fused, _) = run_with(plan, fixture, prog, true);
            let (interp, _) = run_with(plan, fixture, prog, false);
            // The all-zero weight vector makes element 7 NaN (0/0) on
            // both paths — bit comparison is the whole point here.
            assert!(fused.as_dbl_vec().unwrap()[6].is_nan(), "{plan}: zero-den corner lost");
            assert_eq!(bits(&fused), bits(&interp), "{plan} / {prog}: diverges");
        }
    }
    let recognized_before = fusion::contexts_recognized();
    let fused_before = fusion::slices_fused();
    run_with("plan(sequential)", fixture, "unlist(lapply(ws, stat) |> futurize())", true);
    assert!(fusion::contexts_recognized() > recognized_before, "boot body must match");
    assert!(fusion::slices_fused() > fused_before, "boot slices must fuse");
}

#[test]
fn gram_body_bit_identical_across_backends() {
    let _g = serial();
    worker_env();
    let fixture = "
        y <- c(1, 0, 1)
        blocks <- lapply(1:4, function(i) list(c(1, 2, 3) * i, c(0.5, -1, 2)))
        g <- function(x) hlo_gram(x, y)
    ";
    let prog = "lapply(blocks, g) |> futurize()";
    let reference = run_with("plan(sequential)", fixture, prog, false).0;
    for plan in PLANS {
        let (fused, _) = run_with(plan, fixture, prog, true);
        // Nested lists of finite doubles: RVal equality is exact here.
        assert_eq!(fused, reference, "{plan}: gram result diverges");
    }
    let recognized_before = fusion::contexts_recognized();
    let fused_before = fusion::slices_fused();
    run_with("plan(sequential)", fixture, prog, true);
    assert!(fusion::contexts_recognized() > recognized_before, "gram body must match");
    assert!(fusion::slices_fused() > fused_before, "gram slices must fuse");
}

#[test]
fn ridge_body_bit_identical_across_backends() {
    let _g = serial();
    worker_env();
    let fixture = "
        y <- c(1, 0, 1)
        blocks <- lapply(1:4, function(i) list(c(1, 2, 3) * i, c(0.5, -1, 2)))
        r <- function(x) hlo_ridge(x, y, 0.5)
    ";
    let prog = "lapply(blocks, r) |> futurize()";
    let reference = run_with("plan(sequential)", fixture, prog, false).0;
    for plan in PLANS {
        let (fused, _) = run_with(plan, fixture, prog, true);
        // Coefficient vectors of finite doubles: equality is exact here
        // (both paths run the same gram + Cholesky f64 arithmetic).
        assert_eq!(fused, reference, "{plan}: ridge result diverges");
    }
    let recognized_before = fusion::contexts_recognized();
    let fused_before = fusion::slices_fused();
    run_with("plan(sequential)", fixture, prog, true);
    assert!(fusion::contexts_recognized() > recognized_before, "ridge body must match");
    assert!(fusion::slices_fused() > fused_before, "ridge slices must fuse");
}

#[test]
fn kill_switch_suppresses_recognition_entirely() {
    let _g = serial();
    let recognized_before = fusion::contexts_recognized();
    let unmatched_before = fusion::contexts_unmatched();
    let fused_before = fusion::slices_fused();
    let (v, _) = run_with(
        "plan(multicore, workers = 2)",
        "f <- function(x) x * 2 + 1",
        "future_sapply(c(1.0, 2.0, 3.0), f)",
        false,
    );
    assert_eq!(v.as_dbl_vec().unwrap(), vec![3.0, 5.0, 7.0]);
    assert_eq!(fusion::contexts_recognized(), recognized_before, "kill switch leaked");
    assert_eq!(fusion::contexts_unmatched(), unmatched_before, "disabled ≠ unmatched");
    assert_eq!(fusion::slices_fused(), fused_before, "kill switch must stop dispatch");
}

/// Satellite: the per-worker inner-backend cache. Eight outer chunks on
/// two multicore workers, each running a nested map under an inherited
/// `multisession(2)` level, must spawn the inner pool once per worker
/// thread (2 spawns each) — not once per chunk (16 spawns).
#[test]
fn nested_multisession_spawns_once_per_worker_not_per_chunk() {
    let _g = serial();
    worker_env();
    let prog = "unlist(lapply(1:8, function(x) \
        sum(future_sapply(1:2, function(y) y * 1.0 + x))) |> futurize(scheduling = 4))";
    let reference = {
        let mut s = Session::new();
        s.eval_str("plan(sequential)").unwrap();
        s.eval_str(prog).unwrap()
    };
    let spawned_before = multisession::workers_spawned();
    let mut s = Session::new();
    s.eval_str("plan(list(multicore(2), multisession(2)))").unwrap();
    let v = s.eval_str(prog).unwrap();
    assert_eq!(bits(&v), bits(&reference), "cached inner backends must not change results");
    let spawned = multisession::workers_spawned() - spawned_before;
    assert!(
        (2..=4).contains(&spawned),
        "inner multisession(2) must spawn once per outer worker \
         (expected 2-4 worker processes, saw {spawned})"
    );
}
