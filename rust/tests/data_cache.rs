//! Content-addressed data-plane cache: differential + fault-injection
//! suite (PR 9 tentpole).
//!
//! The cache is a pure transport optimization, so its contract is
//! *observational equivalence*: with `FUTURIZE_NO_CACHE=1` (or
//! `futurize(cache = "off")`) every map must produce bit-identical
//! values, relay text, and seeded draws — on every backend, at nesting
//! depths 1 and 2. On top of that, the parent-side ledger must actually
//! save bytes (a second identical map ships zero blobs), a cold or
//! evicted worker must recover through the `CacheMiss` negative-ack
//! re-put path (never wedge), and supervision respawn must replay only
//! the blobs of still-active contexts.
//!
//! Every test serializes on one mutex: the kill switches are process
//! env vars and the cache counters are process globals.

mod common;

use std::sync::{Mutex, MutexGuard, OnceLock};

use common::{within, worker_env};
use futurize::backend::{blobstore, multisession};
use futurize::prelude::*;
use futurize::wire::stats;

fn serial() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    // A panicked test must not wedge the rest of the suite.
    LOCK.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
}

/// Run `f` with the cache forced on or off, restoring the ambient state
/// (which CI may pin to off for the differential leg) afterwards.
fn with_cache<T>(on: bool, f: impl FnOnce() -> T) -> T {
    let ambient = std::env::var(blobstore::NO_CACHE_ENV).ok();
    if on {
        std::env::remove_var(blobstore::NO_CACHE_ENV);
    } else {
        std::env::set_var(blobstore::NO_CACHE_ENV, "1");
    }
    let r = f();
    match ambient {
        Some(v) => std::env::set_var(blobstore::NO_CACHE_ENV, v),
        None => std::env::remove_var(blobstore::NO_CACHE_ENV),
    }
    r
}

fn run_with(plan: &str, fixture: &str, prog: &str, cache: bool) -> (RVal, String) {
    with_cache(cache, || {
        let mut s = Session::new();
        s.eval_str(plan).unwrap_or_else(|e| panic!("{plan}: {e}"));
        s.eval_str("futureSeed(99)").unwrap();
        s.eval_str(fixture).unwrap();
        let (r, out) = s.eval_captured(prog);
        (r.unwrap_or_else(|e| panic!("{plan} / {prog}: {e}")), out)
    })
}

const PLANS: &[&str] = &[
    "plan(sequential)",
    "plan(multicore, workers = 2)",
    "plan(multisession, workers = 2)",
    "plan(cluster, workers = c(\"n1\", \"n2\"), latency_ms = 0.1)",
    "plan(cluster_tcp, workers = 2)",
    "plan(future.batchtools::batchtools_slurm, workers = 2, poll_ms = 2)",
];

/// Bit pattern of a numeric result — the seeded fixtures compare draws,
/// where `assert_eq!` on f64 would hide sign-of-zero/NaN differences.
fn bits(v: &RVal) -> Vec<u64> {
    v.as_dbl_vec().unwrap().iter().map(|x| x.to_bits()).collect()
}

/// ~80 KiB captured global — over `CACHE_MIN_BYTES`, so it rides the
/// cache on process backends; items stay small and ship inline.
const BIG_FIXTURE: &str = "
    d <- sin(1:10000)
    f <- function(x) sum(d) + x
";

#[test]
fn cache_on_off_bit_identical_on_every_backend() {
    let _g = serial();
    worker_env();
    let prog = "future_sapply(c(-1.5, 0, 2.5, 4, 7, 11), f)";
    for plan in PLANS {
        let (cached, cached_out) = run_with(plan, BIG_FIXTURE, prog, true);
        let (plain, plain_out) = run_with(plan, BIG_FIXTURE, prog, false);
        assert_eq!(bits(&cached), bits(&plain), "{plan}: value bits diverge");
        assert_eq!(cached_out, plain_out, "{plan}: relay text diverges");
    }
}

#[test]
fn cache_on_off_bit_identical_with_seeds_and_conditions() {
    let _g = serial();
    worker_env();
    // Seeded draws plus a relayed warning per element: the cache must
    // not perturb RNG stream assignment or the ordered relay.
    let prog = "unlist(lapply(1:6, function(x) { \
                 warning(paste(\"w\", x))\nrnorm(1) * 1e-9 + sum(d) * x }) \
                 |> futurize(seed = TRUE, chunk_size = 1))";
    for plan in PLANS {
        let (cached, cached_out) = run_with(plan, BIG_FIXTURE, prog, true);
        let (plain, plain_out) = run_with(plan, BIG_FIXTURE, prog, false);
        assert_eq!(bits(&cached), bits(&plain), "{plan}: seeded bits diverge");
        assert_eq!(cached_out, plain_out, "{plan}: condition relay diverges");
    }
}

#[test]
fn cache_on_off_bit_identical_at_depth_two() {
    let _g = serial();
    worker_env();
    // The oversized global is captured by the *outer* body; the nested
    // map runs on the inherited inner stack of the respawned topology.
    let prog = "unlist(lapply(1:4, function(x) \
                 sum(future_sapply(1:3, function(y) y * x)) + sum(d)) \
                 |> futurize(chunk_size = 1))";
    for plan in
        ["plan(list(multisession(2), multicore(2)))", "plan(list(multicore(2), multicore(2)))"]
    {
        let (cached, _) = run_with(plan, BIG_FIXTURE, prog, true);
        let (plain, _) = run_with(plan, BIG_FIXTURE, prog, false);
        assert_eq!(bits(&cached), bits(&plain), "{plan}: depth-2 bits diverge");
    }
}

#[test]
fn second_identical_map_ships_zero_blobs() {
    let _g = serial();
    worker_env();
    with_cache(true, || {
        within(60, "ledger reuse", || {
            let mut s = Session::new();
            s.eval_str("plan(multisession, workers = 2)").unwrap();
            s.eval_str(BIG_FIXTURE).unwrap();
            stats::reset();
            let r1 = s.eval_str("future_sapply(1:6, f)").unwrap();
            let puts_first = stats::cache_puts();
            let put_bytes_first = stats::cache_put_bytes();
            assert!(puts_first >= 1, "first map must ship the oversized global");
            assert!(
                put_bytes_first as usize >= blobstore::CACHE_MIN_BYTES,
                "{put_bytes_first} put bytes for an ~80 KiB blob"
            );
            let r2 = s.eval_str("future_sapply(1:6, f)").unwrap();
            assert_eq!(
                stats::cache_puts(),
                puts_first,
                "second identical map re-shipped resident blobs"
            );
            assert!(stats::cache_hits() > 0, "resident digests must count as hits");
            assert!(
                stats::cache_hit_bytes() as usize >= blobstore::CACHE_MIN_BYTES,
                "hit accounting must credit the blob bytes saved"
            );
            assert_eq!(bits(&r1), bits(&r2));
        });
    });
}

#[test]
fn per_call_cache_off_ships_nothing() {
    let _g = serial();
    worker_env();
    with_cache(true, || {
        within(60, "cache = off", || {
            let mut s = Session::new();
            s.eval_str("plan(multisession, workers = 2)").unwrap();
            s.eval_str(BIG_FIXTURE).unwrap();
            stats::reset();
            let off = s
                .eval_str("unlist(lapply(1:6, f) |> futurize(cache = \"off\"))")
                .unwrap();
            assert_eq!(stats::cache_puts(), 0, "cache = \"off\" must not extract blobs");
            let on = s.eval_str("unlist(lapply(1:6, f) |> futurize())").unwrap();
            assert!(stats::cache_puts() > 0, "cache = \"auto\" default must extract");
            assert_eq!(bits(&off), bits(&on));
        });
    });
}

#[test]
fn intra_call_alias_dedup_encodes_once() {
    let _g = serial();
    worker_env();
    // Two bindings whose frozen values are structurally identical must
    // ship as ONE blob (content addressing dedups by digest).
    let fixture = "
        a <- sin(1:10000)
        b <- sin(1:10000)
        f <- function(x) sum(a) + sum(b) + x
    ";
    let reference = {
        let (r, _) = run_with("plan(sequential)", fixture, "future_sapply(1:4, f)", false);
        bits(&r)
    };
    with_cache(true, || {
        within(60, "alias dedup", move || {
            let mut s = Session::new();
            s.eval_str("plan(multisession, workers = 1)").unwrap();
            s.eval_str(fixture).unwrap();
            stats::reset();
            let r = s.eval_str("future_sapply(1:4, f)").unwrap();
            assert_eq!(
                stats::cache_puts(),
                1,
                "aliased globals must dedup to a single CachePut"
            );
            assert_eq!(bits(&r), reference, "deduped map diverged");
        });
    });
}

#[test]
fn evicted_blob_recovers_through_cache_miss_reput() {
    let _g = serial();
    worker_env();
    // A 1-byte budget makes every blob evictable as soon as the next
    // task frame inserts another. Map over X, then Y (evicts X in the
    // worker), then X again: the parent ledger says X is resident, the
    // worker answers CacheMiss, the parent re-puts, the map completes.
    with_cache(true, || {
        std::env::set_var(blobstore::CACHE_BYTES_ENV, "1");
        let got = within(90, "cache-miss repair", || {
            let mut s = Session::new();
            s.eval_str("plan(multisession, workers = 1)").unwrap();
            s.eval_str("x <- sin(1:10000)\ny <- cos(1:10000)").unwrap();
            stats::reset();
            let r1 = s.eval_str("future_sapply(1:2, function(i) sum(x) * i)").unwrap();
            s.eval_str("invisible(future_sapply(1:2, function(i) sum(y) * i))").unwrap();
            let misses_before = stats::cache_misses();
            let r3 = s.eval_str("future_sapply(1:2, function(i) sum(x) * i)").unwrap();
            (bits(&r1), bits(&r3), stats::cache_misses() - misses_before)
        });
        std::env::remove_var(blobstore::CACHE_BYTES_ENV);
        let (r1, r3, misses) = got;
        assert!(misses > 0, "the evicted blob must be re-requested via CacheMiss");
        assert_eq!(r1, r3, "the re-put map diverged");
    });
}

#[test]
fn evicted_blob_recovers_through_cache_miss_reput_over_tcp() {
    let _g = serial();
    worker_env();
    // Same eviction scenario as above, but across a real socket: the
    // worker's CacheMiss negative-ack and the parent's re-put + task
    // redelivery must resolve over TCP framing exactly as over stdio.
    with_cache(true, || {
        std::env::set_var(blobstore::CACHE_BYTES_ENV, "1");
        let got = within(90, "tcp cache-miss repair", || {
            let mut s = Session::new();
            s.eval_str("plan(cluster_tcp, workers = 1)").unwrap();
            s.eval_str("x <- sin(1:10000)\ny <- cos(1:10000)").unwrap();
            stats::reset();
            let r1 = s.eval_str("future_sapply(1:2, function(i) sum(x) * i)").unwrap();
            s.eval_str("invisible(future_sapply(1:2, function(i) sum(y) * i))").unwrap();
            let misses_before = stats::cache_misses();
            let r3 = s.eval_str("future_sapply(1:2, function(i) sum(x) * i)").unwrap();
            (bits(&r1), bits(&r3), stats::cache_misses() - misses_before)
        });
        std::env::remove_var(blobstore::CACHE_BYTES_ENV);
        let (r1, r3, misses) = got;
        assert!(misses > 0, "the evicted blob must be re-requested via CacheMiss over TCP");
        assert_eq!(r1, r3, "the re-put TCP map diverged");
    });
}

#[test]
fn respawn_replays_only_active_context_blobs() {
    let _g = serial();
    worker_env();
    // Map 1 (context A, blob `a`) completes and drops its context; map
    // 2 (context B, blob `b`) is killed mid-map. The replacement worker
    // must receive a replay of exactly context B's blob — context A is
    // gone, so its blob must not ride along — and the retried chunk
    // must reproduce the sequential seeded reference bit-for-bit.
    let reference: Vec<u64> = {
        let mut s = Session::new();
        s.eval_str("futureSeed(77)").unwrap();
        s.eval_str("a <- sin(1:10000)\nb <- cos(1:10000)").unwrap();
        s.eval_str("invisible(unlist(lapply(1:4, function(i) sum(a) * i) |> futurize()))")
            .unwrap();
        bits(
            &s.eval_str(
                "unlist(lapply(1:4, function(i) rnorm(1) * 1e-9 + sum(b) * i) \
                 |> futurize(seed = TRUE, chunk_size = 1))",
            )
            .unwrap(),
        )
    };
    let marker =
        std::env::temp_dir().join(format!("futurize-cache-kill-{}", std::process::id()));
    let _ = std::fs::remove_file(&marker);
    let marker_str = marker.display().to_string();
    let (got, out, replayed) = with_cache(true, || {
        within(90, "respawn blob replay", move || {
            let mut s = Session::new();
            s.eval_str("plan(multisession, workers = 2)").unwrap();
            s.eval_str("futureSeed(77)").unwrap();
            s.eval_str("a <- sin(1:10000)\nb <- cos(1:10000)").unwrap();
            s.eval_str("invisible(unlist(lapply(1:4, function(i) sum(a) * i) |> futurize()))")
                .unwrap();
            let replayed_before = multisession::blobs_replayed();
            let (r, out) = s.eval_captured(&format!(
                "unlist(lapply(1:4, function(i) {{ \
                 if (i == 3) futurize_test_exit_once(\"{marker_str}\")\n\
                 rnorm(1) * 1e-9 + sum(b) * i }}) \
                 |> futurize(seed = TRUE, chunk_size = 1, retries = 1))"
            ));
            let replayed = multisession::blobs_replayed() - replayed_before;
            (bits(&r.unwrap()), out, replayed)
        })
    });
    let _ = std::fs::remove_file(&marker);
    assert!(out.contains("resubmitting"), "expected a retry warning, got: {out:?}");
    assert_eq!(got, reference, "recovered map diverged from the sequential reference");
    assert_eq!(
        replayed, 1,
        "respawn must replay exactly the active context's blob (got {replayed})"
    );
}

#[test]
fn fz009_reports_cache_extraction() {
    let _g = serial();
    use futurize::future_core::driver::MapOptions;
    use futurize::rlite::serialize::WireVal;
    use futurize::transpile::analysis::analyze_map;
    let f = WireVal::Builtin("identity".into());
    let big = WireVal::Dbl(vec![0.5; 10_000], None);
    let small = WireVal::Dbl(vec![0.5; 4], None);
    let diags = with_cache(true, || {
        analyze_map(
            &f,
            &[],
            &[("d".into(), big.clone()), ("k".into(), small)],
            false,
            &MapOptions::default(),
        )
    });
    let fz009: Vec<_> =
        diags.iter().filter(|d| d.code.as_str() == "FZ009").collect();
    assert_eq!(fz009.len(), 1, "{diags:?}");
    assert!(fz009[0].message.contains("`d`"), "{}", fz009[0].message);
    // Opting out (per call or process-wide) silences the report.
    let off_opts = MapOptions { cache: false, ..Default::default() };
    let none = with_cache(true, || {
        analyze_map(&f, &[], &[("d".into(), big.clone())], false, &off_opts)
    });
    assert!(none.iter().all(|d| d.code.as_str() != "FZ009"), "{none:?}");
    let none = with_cache(false, || {
        analyze_map(&f, &[], &[("d".into(), big)], false, &MapOptions::default())
    });
    assert!(none.iter().all(|d| d.code.as_str() != "FZ009"), "{none:?}");
}
