//! Exp T1 — Table-1 equivalence as a test (the bench regenerates the
//! timing table; this locks in the correctness half): every supported
//! map-reduce function yields identical results futurized vs sequential,
//! and the transpiler registry covers exactly the paper's tables.

use futurize::prelude::*;

#[test]
fn registry_lists_match_paper_tables() {
    use futurize::transpile::{is_supported, supported_functions, supported_packages};

    // §3.4: futurize_supported_packages() output.
    assert_eq!(
        supported_packages(),
        vec![
            "BiocParallel",
            "base",
            "boot",
            "caret",
            "crossmap",
            "foreach",
            "glmnet",
            "lme4",
            "mgcv",
            "plyr",
            "purrr",
            "stats",
            "tm",
        ]
    );

    // Table 1, base row (§3.4 example shows the base list).
    for f in [
        "lapply", "sapply", "tapply", "vapply", "mapply", ".mapply", "Map", "eapply", "apply",
        "by", "replicate", "Filter",
    ] {
        assert!(is_supported("base", f), "base::{f}");
    }
    assert!(is_supported("stats", "kernapply"));
    assert!(is_supported("foreach", "%do%"));
    // Table 2 rows.
    for (pkg, f) in [
        ("boot", "boot"),
        ("boot", "censboot"),
        ("boot", "tsboot"),
        ("caret", "train"),
        ("caret", "bag"),
        ("caret", "gafs"),
        ("caret", "nearZeroVar"),
        ("caret", "rfe"),
        ("caret", "safs"),
        ("caret", "sbf"),
        ("glmnet", "cv.glmnet"),
        ("lme4", "allFit"),
        ("lme4", "bootMer"),
        ("mgcv", "bam"),
        ("mgcv", "predict.bam"),
        ("tm", "TermDocumentMatrix"),
        ("tm", "tm_index"),
        ("tm", "tm_map"),
    ] {
        assert!(is_supported(pkg, f), "{pkg}::{f}");
    }
    // Spot check function listings are sorted and non-empty.
    let fns = supported_functions("purrr");
    assert!(fns.len() >= 20, "purrr variants: {fns:?}");
    let mut sorted = fns.clone();
    sorted.sort();
    assert_eq!(fns, sorted);
}

/// Every transpilable function must have a registered implementation for
/// both its sequential name and its transpile target — i.e. futurize()
/// of a supported call must *evaluate*, not just rewrite.
#[test]
fn every_table1_function_futurizes_and_matches() {
    let fixture = "
        f <- function(x) x^2
        g2 <- function(a, b) a + b
        xs <- 1:6
        ys <- 11:16
        vals <- c(1, 5, 2, 8, 3, 9)
        grp <- c(\"a\", \"b\", \"a\", \"b\", \"a\", \"b\")
        m <- matrix(1:12, nrow = 3)
        df <- data.frame(g = grp, v = vals)
        e <- new.env()
        e$a <- 1
        k3 <- c(0.25, 0.5, 0.25)
        named <- c(p = 1, q = 2)
    ";
    let cases = [
        "lapply(xs, f)",
        "sapply(xs, f)",
        "vapply(xs, f, numeric(1))",
        "mapply(g2, xs, ys)",
        ".mapply(g2, list(xs, ys), NULL)",
        "Map(g2, xs, ys)",
        "apply(m, 2, sum)",
        "apply(m, 1, sum)",
        "tapply(vals, grp, sum)",
        "by(df, grp, function(d) sum(d$v))",
        "eapply(e, f)",
        "Filter(function(x) x > 2, xs)",
        "kernapply(vals, k3)",
        "map(xs, f)",
        "map_dbl(xs, f)",
        "map_lgl(xs, function(x) x > 3)",
        "map_int(xs, function(x) x * 2L)",
        "map2(xs, ys, g2)",
        "map2_dbl(xs, ys, g2)",
        "pmap(list(xs, ys), g2)",
        "pmap_dbl(list(xs, ys), g2)",
        "imap(named, function(x, nm) paste0(nm, x))",
        "imap_chr(named, function(x, nm) paste0(nm, x))",
        "modify(xs, f)",
        "modify_if(xs, function(x) x > 3, f)",
        "modify_at(xs, c(1, 2), f)",
        "map_if(xs, function(x) x > 3, f)",
        "map_at(xs, c(2, 3), f)",
        "invoke_map(list(function() 1, function() 2))",
        "walk(xs, f)",
        "crossmap::xmap(list(1:3, 1:2), g2)",
        "crossmap::xmap_dbl(list(1:3, 1:2), g2)",
        "crossmap::map_vec(xs, f)",
        "crossmap::map2_vec(xs, ys, g2)",
        "crossmap::pmap_vec(list(xs, ys), g2)",
        "crossmap::imap_vec(named, function(x, nm) x * 2)",
        "foreach(x = xs, .combine = c) %do% { f(x) }",
        "foreach(a = xs, b = ys) %do% { a + b }",
        "llply(xs, f)",
        "laply(xs, f)",
        "ldply(xs, function(x) list(v = x, w = x * 2))",
        "alply(xs, f)",
        "aaply(xs, f)",
        "adply(xs, function(x) list(v = x))",
        "ddply(df, \"g\", function(d) list(s = sum(d$v)))",
        "dlply(df, \"g\", function(d) sum(d$v))",
        "daply(df, \"g\", function(d) sum(d$v))",
        "mlply(data.frame(a = 1:3, b = 4:6), g2)",
        "maply(data.frame(a = 1:3, b = 4:6), g2)",
        "mdply(data.frame(a = 1:3, b = 4:6), function(a, b) list(s = a + b))",
        "bplapply(xs, f)",
        "bpmapply(g2, xs, ys)",
        "bpvec(vals, function(v) v * 2)",
        "bpaggregate(vals, grp, sum)",
    ];
    for case in cases {
        let mut s1 = Session::new();
        s1.eval_str(fixture).unwrap();
        let seq = s1.eval_str(case).unwrap_or_else(|e| panic!("{case} (seq): {e}"));

        let mut s2 = Session::new();
        s2.eval_str("plan(multicore, workers = 3)").unwrap();
        s2.eval_str(fixture).unwrap();
        let fut = s2
            .eval_str(&format!("{case} |> futurize()"))
            .unwrap_or_else(|e| panic!("{case} (futurized): {e}"));
        assert_eq!(seq, fut, "futurized result differs for: {case}");
    }
}

/// Seeded (resampling) functions: reproducible under futurize, not
/// equal to the sequential session-RNG draw (documented difference —
/// same as future.apply).
#[test]
fn seeded_functions_are_reproducible() {
    for case in [
        "replicate(5, rnorm(3))",
        "times(5) %do% rnorm(3)",
    ] {
        let draw = |workers: usize| {
            let mut s = Session::new();
            s.eval_str(&format!("plan(multicore, workers = {workers})")).unwrap();
            s.eval_str("futureSeed(17)").unwrap();
            s.eval_str(&format!("{case} |> futurize()")).unwrap()
        };
        assert_eq!(draw(1), draw(3), "{case}");
    }
}

#[test]
fn unified_options_accepted_by_every_family() {
    let fixture = "xs <- 1:8\nf <- function(x) x + 1";
    for case in [
        "lapply(xs, f)",
        "map(xs, f)",
        "foreach(x = xs) %do% { f(x) }",
        "llply(xs, f)",
        "bplapply(xs, f)",
    ] {
        let mut s = Session::new();
        s.eval_str("plan(multicore, workers = 2)").unwrap();
        s.eval_str(fixture).unwrap();
        // The same unified options work across all APIs (§2.4).
        s.eval_str(&format!(
            "{case} |> futurize(seed = TRUE, chunk_size = 2, scheduling = 1, stdout = TRUE, conditions = TRUE)"
        ))
        .unwrap_or_else(|e| panic!("{case}: {e}"));
    }
}
