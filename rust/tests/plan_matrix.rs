//! Differential conformance matrix for plan stacks (ISSUE 5): every
//! API family × every backend × stack depths 1–2 must produce
//!
//! - **identical results** to the `plan(sequential)` reference,
//! - **identical condition/stdout relay text** (the ordered-relay
//!   contract: what the user sees cannot depend on topology), and
//! - **bit-identical `seed = TRUE` draws** (per-element L'Ecuyer
//!   streams fork per nesting level, so the whole RNG tree depends only
//!   on the root seed and element indices — never on chunking, backend,
//!   or stack shape).
//!
//! Runs under both wire codecs: CI re-executes this file with
//! `FUTURIZE_WIRE_CODEC=json`.

mod common;

use common::worker_env;
use futurize::prelude::*;

/// (name, depth-1 plan, depth-2 plan). The depth-2 stacks put
/// `multicore(2)` underneath so every outer backend is exercised with a
/// real parallel inner level.
const BACKENDS: &[(&str, &str, &str)] = &[
    ("sequential", "plan(sequential)", "plan(list(sequential, multicore(2)))"),
    (
        "multicore",
        "plan(multicore, workers = 2)",
        "plan(list(multicore(2), multicore(2)))",
    ),
    (
        "multisession",
        "plan(multisession, workers = 2)",
        "plan(list(multisession(2), multicore(2)))",
    ),
    (
        "cluster",
        "plan(cluster, workers = c(\"n1\", \"n2\"), latency_ms = 0.1)",
        "plan(list(tweak(cluster, workers = c(\"n1\", \"n2\"), latency_ms = 0.1), multicore(2)))",
    ),
    (
        "batchtools",
        "plan(future.batchtools::batchtools_slurm, workers = 2, poll_ms = 2)",
        "plan(list(tweak(future.batchtools::batchtools_slurm, workers = 2, poll_ms = 2), \
         multicore(2)))",
    ),
];

/// Depth-1 fixture: element function emits a message + stdout and draws
/// from its per-element stream.
const FIXTURE_D1: &str = "
    xs <- 1:4
    f1 <- function(x) {
      message(paste0(\"m\", x))
      cat(paste0(\"c\", x, \" \"))
      rnorm(1) * 0.001 + x * 10
    }
";

/// Depth-2 fixture: the element function additionally runs a *nested*
/// futurized map (with its own messages and seeded draws) that the
/// inherited stack level executes.
const FIXTURE_D2: &str = "
    xs <- 1:4
    f2 <- function(x) {
      message(paste0(\"m\", x))
      inner <- future_sapply(1:3, function(y) {
        message(paste0(\"n\", x, y))
        rnorm(1) * 0.001 + y * x
      }, future.seed = TRUE)
      sum(inner) + rnorm(1) * 0.001 + x * 100
    }
";

/// The API families of the paper's Table 1, each invoked through its
/// own surface (`fn_name` is substituted for f1/f2 per depth).
const FAMILIES: &[(&str, &str)] = &[
    ("lapply", "unlist(lapply(xs, FN) |> futurize(seed = TRUE))"),
    ("purrr::map", "map_dbl(xs, FN) |> futurize(seed = TRUE)"),
    (
        "foreach",
        "unlist((foreach(x = xs, .combine = c) %do% { FN(x) }) |> futurize(seed = TRUE))",
    ),
    ("future_apply", "future_sapply(xs, FN, future.seed = TRUE)"),
    (
        "furrr",
        "future_map_dbl(xs, FN, .options = furrr_options(seed = TRUE))",
    ),
    ("BiocParallel", "unlist(bplapply(xs, FN) |> futurize(seed = TRUE))"),
];

fn run_cell(plan_stmt: &str, fixture: &str, program: &str) -> (RVal, String) {
    let mut s = Session::new();
    s.eval_str(plan_stmt).unwrap_or_else(|e| panic!("{plan_stmt}: {e}"));
    s.eval_str("futureSeed(99)").unwrap();
    s.eval_str(fixture).unwrap();
    let (r, out) = s.eval_captured(program);
    let v = r.unwrap_or_else(|e| panic!("{plan_stmt} / {program}: {e}"));
    (v, out)
}

fn matrix_for_depth(depth: usize) {
    worker_env();
    let (fixture, fn_name) = match depth {
        1 => (FIXTURE_D1, "f1"),
        _ => (FIXTURE_D2, "f2"),
    };
    for (family, template) in FAMILIES {
        let program = template.replace("FN", fn_name);
        // The reference is always flat plan(sequential): a nested
        // futurized call under it degrades to the implicit sequential
        // inner level, which every stack shape must match bit-for-bit.
        let (ref_val, ref_out) = run_cell("plan(sequential)", fixture, &program);
        assert!(
            ref_out.contains("m1"),
            "{family}: fixture lost its relay output: {ref_out:?}"
        );
        if depth == 2 {
            assert!(ref_out.contains("n23"), "{family}: nested relay lost: {ref_out:?}");
        }
        for (backend, plan1, plan2) in BACKENDS {
            let plan_stmt = if depth == 1 { plan1 } else { plan2 };
            let (val, out) = run_cell(plan_stmt, fixture, &program);
            assert_eq!(
                val, ref_val,
                "{family} × {backend} × depth {depth}: results differ from sequential"
            );
            assert_eq!(
                out, ref_out,
                "{family} × {backend} × depth {depth}: relay text/order differs"
            );
        }
    }
}

#[test]
fn matrix_depth1_all_families_all_backends() {
    matrix_for_depth(1);
}

#[test]
fn matrix_depth2_all_families_all_backends() {
    matrix_for_depth(2);
}

/// The ISSUE 5 acceptance demo: `plan(list(multisession(2),
/// multicore(2)))` runs a nested map with 4-way effective parallelism —
/// both outer workers appear in the trace and each task reports a
/// 2-worker inner backend — while results and seeded draws stay
/// bit-identical to `plan(sequential)`.
#[test]
fn nested_stack_gives_outer_times_inner_parallelism() {
    worker_env();
    const PROG: &str = "unlist(lapply(1:4, function(x) \
        sum(future_sapply(1:4, function(y) { Sys.sleep(0.01)\n\
        rnorm(1) * 0.001 + y * x }, future.seed = TRUE))) |> futurize(seed = TRUE))";
    let reference = {
        let mut s = Session::new();
        s.eval_str("plan(sequential)\nfutureSeed(7)").unwrap();
        s.eval_str(PROG).unwrap()
    };
    let mut s = Session::new();
    s.eval_str("plan(list(multisession(2), multicore(2)))\nfutureSeed(7)").unwrap();
    let v = s.eval_str(PROG).unwrap();
    assert_eq!(v, reference, "stacked results must be bit-identical to sequential");
    let trace = s.last_trace();
    let outer: std::collections::HashSet<usize> = trace.iter().map(|e| e.worker).collect();
    assert_eq!(outer.len(), 2, "both outer workers must run chunks: {trace:?}");
    assert!(
        trace.iter().all(|e| e.inner_workers == 2),
        "every chunk must report its 2-worker inner backend: {trace:?}"
    );
    // Under the flat sequential plan the same program reports the
    // implicit (1-worker) inner level, not a parallel one.
    let mut s = Session::new();
    s.eval_str("plan(sequential)\nfutureSeed(7)").unwrap();
    s.eval_str(PROG).unwrap();
    assert!(s.last_trace().iter().all(|e| e.inner_workers <= 1), "{:?}", s.last_trace());
}

/// The unseeded-outer corner: a nested seed = TRUE map under an outer
/// map *without* seed management must still be topology-invariant (the
/// nested-root baseline is re-pinned per element, not leaked across the
/// elements sharing one worker session), while sibling seeded maps
/// inside one element still draw different numbers.
#[test]
fn unseeded_outer_with_seeded_nested_is_topology_invariant() {
    const PROG: &str = "unlist(lapply(1:4, function(x) { \
        a <- sum(future_sapply(1:2, function(y) rnorm(1), future.seed = TRUE))\n\
        b <- sum(future_sapply(1:2, function(y) rnorm(1), future.seed = TRUE))\n\
        if (a == b) stop(\"sibling seeded maps drew identical streams\")\n\
        a * 1000 + b + x }) |> futurize())";
    let run = |plan: &str| {
        let mut s = Session::new();
        s.eval_str(plan).unwrap();
        s.eval_str(PROG).unwrap_or_else(|e| panic!("{plan}: {e}"))
    };
    let reference = run("plan(sequential)");
    assert_eq!(run("plan(list(multicore(2), sequential))"), reference);
    assert_eq!(run("plan(list(multicore(4), multicore(2)))"), reference);
    // futureSeed() steers nested seeded maps even under an unseeded
    // outer: the parent root rides to workers inside NestingInfo.
    let seeded = |seed: u64| {
        let mut s = Session::new();
        s.eval_str("plan(multicore, workers = 2)").unwrap();
        s.eval_str(&format!("futureSeed({seed})")).unwrap();
        s.eval_str(PROG).unwrap()
    };
    assert_eq!(seeded(5), seeded(5), "same root seed must reproduce");
    assert_ne!(seeded(5), seeded(6), "nested draws must respect futureSeed()");
}

/// nbrOfWorkers() reports the stack's top level; consuming one level in
/// a worker session exposes the next one (observable via a futurized
/// map that returns the worker-side nbrOfWorkers()).
#[test]
fn workers_see_the_inherited_stack() {
    let mut s = Session::new();
    s.eval_str("plan(list(multicore(2), multicore(3)))").unwrap();
    let top = s.eval_str("nbrOfWorkers()").unwrap();
    assert_eq!(top, RVal::scalar_int(2));
    let inner = s
        .eval_str("unlist(lapply(1:2, function(x) nbrOfWorkers()) |> futurize())")
        .unwrap();
    assert_eq!(inner.as_dbl_vec().unwrap(), vec![3.0, 3.0], "workers must see level 2");
    // Depth exhausted: the implicit inner level is sequential.
    let mut s = Session::new();
    s.eval_str("plan(multicore, workers = 2)").unwrap();
    let inner = s
        .eval_str("unlist(lapply(1:2, function(x) nbrOfWorkers()) |> futurize())")
        .unwrap();
    assert_eq!(inner.as_dbl_vec().unwrap(), vec![1.0, 1.0]);
}
