//! Tests for the streaming dispatch core (`future_core::dispatch`):
//! backpressure invariant, straggler elimination under adaptive
//! chunking, chunking-invariance of `seed = TRUE`, and the O(workers)
//! serialized-payload property of shared task contexts.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use futurize::backend::{Backend, BackendEvent};
use futurize::future_core::{TaskContext, TaskPayload};
use futurize::prelude::*;

fn worker_env() {
    std::env::set_var(
        futurize::backend::worker::WORKER_BIN_ENV,
        env!("CARGO_BIN_EXE_futurize-rs"),
    );
}

// ---------------------------------------------------------------------------
// Backpressure: in-flight chunks never exceed the policy cap.
// ---------------------------------------------------------------------------

/// A delegating backend that records the maximum number of tasks
/// submitted-but-not-yet-done at any point.
struct ProbeBackend {
    inner: Box<dyn Backend>,
    in_flight: Arc<AtomicUsize>,
    max_in_flight: Arc<AtomicUsize>,
}

impl ProbeBackend {
    fn new(inner: Box<dyn Backend>) -> (Self, Arc<AtomicUsize>) {
        let max = Arc::new(AtomicUsize::new(0));
        (
            ProbeBackend {
                inner,
                in_flight: Arc::new(AtomicUsize::new(0)),
                max_in_flight: max.clone(),
            },
            max,
        )
    }

    fn track(&self, ev: &BackendEvent) {
        if let BackendEvent::Done(_) = ev {
            self.in_flight.fetch_sub(1, Ordering::SeqCst);
        }
    }
}

impl Backend for ProbeBackend {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn workers(&self) -> usize {
        self.inner.workers()
    }

    fn register_context(&mut self, ctx: Arc<TaskContext>) -> Result<(), String> {
        self.inner.register_context(ctx)
    }

    fn drop_context(&mut self, ctx_id: u64) -> Result<(), String> {
        self.inner.drop_context(ctx_id)
    }

    fn submit(&mut self, task: TaskPayload) -> Result<(), String> {
        let now = self.in_flight.fetch_add(1, Ordering::SeqCst) + 1;
        self.max_in_flight.fetch_max(now, Ordering::SeqCst);
        self.inner.submit(task)
    }

    fn next_event(&mut self) -> Result<BackendEvent, String> {
        let ev = self.inner.next_event()?;
        self.track(&ev);
        Ok(ev)
    }

    fn try_next_event(&mut self) -> Result<Option<BackendEvent>, String> {
        let ev = self.inner.try_next_event()?;
        if let Some(ev) = &ev {
            self.track(ev);
        }
        Ok(ev)
    }

    fn cancel_queued(&mut self) -> Vec<u64> {
        let ids = self.inner.cancel_queued();
        self.in_flight.fetch_sub(ids.len(), Ordering::SeqCst);
        ids
    }
}

fn probe_session(workers: usize) -> (Session, Arc<AtomicUsize>) {
    let mut s = Session::new();
    s.eval_str(&format!("plan(multicore, workers = {workers})")).unwrap();
    let (probe, max) =
        ProbeBackend::new(Box::new(futurize::backend::multicore::MulticoreBackend::new(workers)));
    s.interp.session.install_backend(Box::new(probe));
    (s, max)
}

#[test]
fn backpressure_bounds_in_flight_chunks() {
    // 64 single-element chunks on 4 workers: the old batch driver put
    // all 64 in flight at once; the streaming core must stay within the
    // policy cap (2 × workers for per-element chunking).
    let (mut s, max) = probe_session(4);
    let v = s
        .eval_str("unlist(lapply(1:64, function(x) x + 1) |> futurize(scheduling = Inf))")
        .unwrap();
    assert_eq!(v.len(), 64);
    let cap = 2 * 4;
    let seen = max.load(Ordering::SeqCst);
    assert!(seen >= 2, "expected concurrent chunks, saw max {seen}");
    assert!(seen <= cap, "in-flight chunks exceeded cap: {seen} > {cap}");
}

#[test]
fn backpressure_bounds_adaptive_chunks() {
    let (mut s, max) = probe_session(3);
    let v = s
        .eval_str(
            "unlist(lapply(1:100, function(x) x * 2) |> futurize(scheduling = \"adaptive\"))",
        )
        .unwrap();
    assert_eq!(v.len(), 100);
    let seen = max.load(Ordering::SeqCst);
    assert!(seen <= 2 * 3, "adaptive in-flight exceeded cap: {seen}");
}

// ---------------------------------------------------------------------------
// Straggler scenario: adaptive chunking beats one-chunk-per-worker.
// ---------------------------------------------------------------------------

#[test]
fn adaptive_beats_static_on_straggler_workload() {
    // 32 elements, 4 workers. Element 1 costs 8 units, the rest 1 unit.
    // Static `scheduling = 1` pins the straggler plus 7 cheap elements
    // on one worker (15 units of wall); guided chunks put it in a
    // 4-element first chunk (~11 units) while the other workers absorb
    // the remainder. Use generous margins: timing test.
    let unit = 0.03; // seconds per cost unit via time_scale
    let run = |opts: &str| -> f64 {
        let mut s = Session::with_config(SessionConfig { time_scale: unit });
        s.eval_str("plan(multicore, workers = 4)").unwrap();
        s.eval_str("f <- function(x) { Sys.sleep(if (x == 1) 8 else 1)\nx }").unwrap();
        // Warm the pool so thread spawn cost is out of the measurement.
        s.eval_str("invisible(lapply(1:4, function(x) x) |> futurize())").unwrap();
        let t0 = std::time::Instant::now();
        let v = s.eval_str(&format!("unlist(lapply(1:32, f) |> futurize({opts}))")).unwrap();
        let dt = t0.elapsed().as_secs_f64();
        assert_eq!(v.len(), 32);
        dt
    };
    let static_t = run("scheduling = 1");
    let adaptive_t = run("scheduling = \"adaptive\"");
    assert!(
        adaptive_t < static_t * 0.85,
        "adaptive should beat static scheduling on a straggler workload: \
         adaptive {adaptive_t:.2}s vs static {static_t:.2}s"
    );
}

// ---------------------------------------------------------------------------
// seed = TRUE must be invariant to adaptive chunking.
// ---------------------------------------------------------------------------

#[test]
fn seed_true_invariant_under_adaptive_chunking() {
    let draw = |opts: &str, workers: usize| -> RVal {
        let mut s = Session::new();
        s.eval_str(&format!("plan(multicore, workers = {workers})")).unwrap();
        s.eval_str("futureSeed(1234)").unwrap();
        s.eval_str(&format!(
            "unlist(lapply(1:16, function(x) rnorm(1)) |> futurize(seed = TRUE{opts}))"
        ))
        .unwrap()
    };
    let reference = draw("", 1);
    assert_eq!(draw(", scheduling = \"adaptive\"", 2), reference);
    assert_eq!(draw(", scheduling = \"adaptive\"", 4), reference);
    assert_eq!(draw(", scheduling = Inf", 3), reference);
}

// ---------------------------------------------------------------------------
// Shared contexts: serialized bytes per map call are O(workers), not
// O(chunks).
// ---------------------------------------------------------------------------

#[test]
fn context_payload_serializes_per_worker_not_per_chunk() {
    worker_env();
    // A closure over a 10k-element integer global, mapped over 48
    // per-element chunks on 2 process workers. The old batch protocol
    // embedded the global in every chunk payload (O(chunks × payload));
    // the shared-context protocol encodes it once (logical) and ships
    // one copy per worker (physical). Under the default binary codec
    // the global is ~22 kB of varints, so the whole call stays well
    // under 200 kB where the per-chunk regime would be megabytes.
    // (Byte counters are thread-local, so concurrent tests don't
    // inflate this.)
    let mut s = Session::new();
    s.eval_str("plan(multisession, workers = 2)").unwrap();
    s.eval_str("big <- 1:10000").unwrap();
    s.eval_str("f <- function(x) x + length(big) * 0").unwrap();
    // Warm the worker pool before measuring.
    s.eval_str("invisible(lapply(1:2, f) |> futurize())").unwrap();
    futurize::wire::stats::reset();
    let v = s
        .eval_str("unlist(lapply(1:48, f) |> futurize(scheduling = Inf))")
        .unwrap();
    assert_eq!(v.len(), 48);
    let physical = futurize::wire::stats::bytes();
    let logical = futurize::wire::stats::logical_bytes();
    assert!(
        physical < 200_000,
        "physical bytes should be O(workers), got {physical} (≈O(chunks × payload)?)"
    );
    // The context is encoded once but written twice (one copy per
    // worker), so physical must exceed logical here.
    assert!(
        logical < physical,
        "expected broadcast copies to make physical ({physical}) > logical ({logical})"
    );
}

// ---------------------------------------------------------------------------
// Zero-copy fast path: in-process backends never encode anything.
// ---------------------------------------------------------------------------

#[test]
fn multicore_fast_path_moves_zero_wire_bytes() {
    let mut s = Session::new();
    s.eval_str("plan(multicore, workers = 2)").unwrap();
    s.eval_str("big <- 1:10000").unwrap();
    s.eval_str("f <- function(x) x + length(big) * 0").unwrap();
    futurize::wire::stats::reset();
    let v = s
        .eval_str("unlist(lapply(1:32, f) |> futurize(scheduling = Inf))")
        .unwrap();
    assert_eq!(v.len(), 32);
    assert_eq!(
        futurize::wire::stats::bytes(),
        0,
        "multicore must not move any physical wire bytes"
    );
    assert_eq!(
        futurize::wire::stats::logical_bytes(),
        0,
        "multicore must not encode any payload at all"
    );
}

#[test]
fn sequential_fast_path_moves_zero_wire_bytes() {
    let mut s = Session::new();
    s.eval_str("plan(sequential)").unwrap();
    futurize::wire::stats::reset();
    let v = s
        .eval_str("unlist(lapply(1:16, function(x) x + 1) |> futurize())")
        .unwrap();
    assert_eq!(v.len(), 16);
    assert_eq!(futurize::wire::stats::bytes(), 0);
    assert_eq!(futurize::wire::stats::logical_bytes(), 0);
}
