//! Local `serde` facade for offline builds.
//!
//! The vendored crate set contains `serde_core` (the implementation) and
//! `serde_derive` (the macros) but not the `serde` facade crate that
//! derive-generated code links against (`extern crate serde as _serde`).
//! This shim plays that role: it re-exports all of serde_core, neutralizes
//! the `__require_serde_not_serde_core!` guard, and provides the
//! `__private228::{de, ser}` helpers the derives reference.

pub use serde_core::*;

/// The guard serde_core arms to reject deriving directly against it; the
/// facade defines it as a no-op (exactly as the real `serde` crate does).
#[macro_export]
macro_rules! __require_serde_not_serde_core {
    () => {};
}

#[doc(hidden)]
pub mod __private228 {
    #[doc(hidden)]
    pub use serde_core::__private228::*;

    #[doc(hidden)]
    pub use core::clone::Clone;
    #[doc(hidden)]
    pub use core::convert::{From, Into, TryFrom};
    #[doc(hidden)]
    pub use core::default::Default;
    #[doc(hidden)]
    pub use core::fmt::{self, Formatter};
    #[doc(hidden)]
    pub use core::marker::PhantomData;
    #[doc(hidden)]
    pub use core::option::Option::{self, None, Some};
    #[doc(hidden)]
    pub use core::result::Result::{self, Err, Ok};
    #[doc(hidden)]
    pub use std::string::String;
    #[doc(hidden)]
    pub use std::vec::Vec;

    /// Used by derive codegen when deserializing identifiers from bytes.
    #[doc(hidden)]
    pub fn from_utf8_lossy(bytes: &[u8]) -> std::borrow::Cow<'_, str> {
        std::string::String::from_utf8_lossy(bytes)
    }

    #[doc(hidden)]
    pub mod de {
        #[doc(hidden)]
        pub use serde_core::__private228::InPlaceSeed;
        use serde_core::de::{Deserialize, Deserializer, Error, Visitor};

        /// Deserialize a missing struct field: succeeds only for types
        /// (like `Option<T>`) that accept "none".
        #[doc(hidden)]
        pub fn missing_field<'de, V, E>(field: &'static str) -> Result<V, E>
        where
            V: Deserialize<'de>,
            E: Error,
        {
            struct MissingFieldDeserializer<E>(&'static str, core::marker::PhantomData<E>);

            impl<'de, E: Error> Deserializer<'de> for MissingFieldDeserializer<E> {
                type Error = E;

                fn deserialize_any<V2: Visitor<'de>>(
                    self,
                    _visitor: V2,
                ) -> Result<V2::Value, E> {
                    Err(Error::missing_field(self.0))
                }

                fn deserialize_option<V2: Visitor<'de>>(
                    self,
                    visitor: V2,
                ) -> Result<V2::Value, E> {
                    visitor.visit_none()
                }

                serde_core::forward_to_deserialize_any! {
                    bool i8 i16 i32 i64 i128 u8 u16 u32 u64 u128 f32 f64 char
                    str string bytes byte_buf unit unit_struct newtype_struct
                    seq tuple tuple_struct map struct enum identifier
                    ignored_any
                }
            }

            let deserializer = MissingFieldDeserializer(field, core::marker::PhantomData);
            Deserialize::deserialize(deserializer)
        }

        #[allow(unused_imports)]
        use serde_core::de::DeserializeSeed as _;
    }

    /// Serialization helpers for exotic enum representations (internally/
    /// adjacently tagged, flatten). This crate's types use the default
    /// externally-tagged representation, so these are not exercised.
    #[doc(hidden)]
    pub mod ser {}
}
