//! Exp F1 — the paper's Figure 1: 8 one-unit tasks, sequential vs
//! futurized on 3 workers. Checks the *shape*: parallel walltime ≈
//! ceil(8/3) task-units, tasks spread across all workers.

use futurize::bench_harness as bh;
use futurize::prelude::*;

const UNIT: f64 = 0.02; // seconds per task (scaled from the paper's 1s)

fn main() {
    futurize::backend::worker::maybe_worker();

    let mut session = Session::with_config(SessionConfig { time_scale: UNIT });
    session
        .eval_str("fcn <- function(x) { Sys.sleep(1)\nx^2 }\nxs <- 1:8")
        .unwrap();

    let seq = bh::bench("figure1", "sequential_8_tasks", 1, 5, || {
        session.eval_str("ys <- lapply(xs, fcn)").unwrap();
    });

    session.eval_str("plan(multicore, workers = 3)").unwrap();
    let par = bh::bench("figure1", "futurized_3_workers", 1, 5, || {
        session
            .eval_str("ys <- lapply(xs, fcn) |> futurize(scheduling = Inf)")
            .unwrap();
    });

    bh::table_header(
        "Figure 1 shape (task-units of walltime; paper: 8 seq vs 3 par)",
        &["variant", "task-units", "ideal"],
    );
    bh::table_row(&["sequential".into(), format!("{:.2}", seq.mean_s / UNIT), "8".into()]);
    bh::table_row(&["futurized(3)".into(), format!("{:.2}", par.mean_s / UNIT), "3".into()]);
    println!("\nspeedup {:.2}x (ideal 2.67x)", seq.mean_s / par.mean_s);
    println!("\ntimeline of the last run:\n{}", session.render_trace());

    let workers: std::collections::HashSet<usize> =
        session.last_trace().iter().map(|e| e.worker).collect();
    assert_eq!(session.last_trace().len(), 8, "8 tasks traced");
    assert!(workers.len() >= 2, "tasks should spread across workers");
    assert!(
        seq.mean_s / par.mean_s > 1.6,
        "parallel run should beat sequential (got {:.2}x)",
        seq.mean_s / par.mean_s
    );
}
