//! Exp X5 — wire-transport cost: JSON text codec vs. compact binary
//! codec vs. the in-process zero-copy fast path.
//!
//! Measures, on a bulk numeric payload (1e6 full-precision doubles;
//! 1e4 in `BENCH_SMOKE=1` mode) and on a realistic multisession
//! protocol stream (shared context + 48 single-element chunks + 48
//! outcomes):
//!
//! - bytes per call for each codec (binary ≈ 8 B/elem on doubles vs
//!   ~19 B/elem JSON; ≥3× total shrink on the protocol stream where
//!   field names/envelopes dominate);
//! - encode+decode ns per element;
//! - the zero-copy path (`WireSlice::shared` windows over `Arc`-frozen
//!   storage), whose per-chunk transport cost is an `Arc` bump — bytes
//!   moved: 0.
//!
//! Results land in `BENCH_wire.json` for the repo's recorded perf
//! trajectory; CI runs the smoke mode on every push.

use std::sync::Arc;

use futurize::bench_harness as bh;
use futurize::future_core::{ContextBody, TaskContext, TaskKind, TaskOutcome, TaskPayload};
use futurize::rlite::serialize::{WireSlice, WireVal};
use futurize::wire::{bin, WireCodec};

fn protocol_stream() -> (Vec<TaskPayload>, Vec<TaskOutcome>, TaskContext) {
    let ctx = TaskContext {
        id: 1,
        body: ContextBody::Map {
            f: WireVal::Builtin("identity".into()),
            extra: vec![],
        },
        globals: vec![(
            "w".to_string(),
            WireVal::Dbl((0..64).map(|k| (k as f64).sin()).collect(), None),
        )],
        cached_globals: vec![],
        nesting: Default::default(),
        kernel: None,
        reduce: None,
    };
    let mut tasks = Vec::new();
    let mut outcomes = Vec::new();
    for k in 0..48u64 {
        tasks.push(TaskPayload {
            id: k,
            kind: TaskKind::MapSlice {
                ctx: 1,
                items: vec![WireVal::Dbl(vec![(k as f64).cos()], None)].into(),
                seeds: None,
            },
            time_scale: 0.0,
            capture_stdout: true,
        });
        outcomes.push(TaskOutcome {
            id: k,
            values: Ok(vec![WireVal::Dbl(vec![2.0 * (k as f64).cos()], None)]),
            log: Default::default(),
            worker: (k % 2) as usize,
            started_unix: 1.769e9 + k as f64,
            finished_unix: 1.769e9 + 0.3 + k as f64,
            nested_workers: 0,
            partial: None,
        });
    }
    (tasks, outcomes, ctx)
}

fn main() {
    futurize::backend::worker::maybe_worker();
    let smoke = bh::smoke_mode();
    let n_elems: usize = if smoke { 10_000 } else { 1_000_000 };
    let iters = if smoke { 2 } else { 5 };
    let mut report = bh::JsonReport::new("BENCH_wire.json");
    report.push_num("payload_elems", n_elems as f64);
    report.push(
        "mode",
        futurize::wire::JsonValue::String(if smoke { "smoke" } else { "full" }.into()),
    );

    // -----------------------------------------------------------------
    // Arm 1: bulk numeric payload (the context-global shipping cost).
    // -----------------------------------------------------------------
    let payload = WireVal::Dbl((0..n_elems).map(|k| (k as f64).sin()).collect(), None);

    let json_bytes = futurize::wire::to_string(&payload).unwrap().len();
    let bin_bytes = bin::to_bytes(&payload).unwrap().len();
    bh::table_header(
        "bulk payload bytes (full-precision doubles)",
        &["codec", "bytes/call", "bytes/elem"],
    );
    for (name, bytes) in [("json", json_bytes), ("binary", bin_bytes), ("zero-copy", 0)] {
        bh::table_row(&[
            name.to_string(),
            format!("{bytes}"),
            format!("{:.2}", bytes as f64 / n_elems as f64),
        ]);
    }
    report.push_num("bulk_dbl_json_bytes", json_bytes as f64);
    report.push_num("bulk_dbl_binary_bytes", bin_bytes as f64);
    report.push_num("bulk_dbl_zero_copy_bytes", 0.0);
    report.push_num("bulk_dbl_shrink_vs_json", json_bytes as f64 / bin_bytes as f64);

    let st = bh::bench("wire", "json_encode_decode", 1, iters, || {
        let s = futurize::wire::to_string(&payload).unwrap();
        let back: WireVal = futurize::wire::from_str(&s).unwrap();
        std::hint::black_box(back);
    });
    report.push_num("bulk_dbl_json_ns_per_elem", st.mean_s * 1e9 / n_elems as f64);

    let st = bh::bench("wire", "binary_encode_decode", 1, iters, || {
        let b = bin::to_bytes(&payload).unwrap();
        let back: WireVal = bin::from_bytes(&b).unwrap();
        std::hint::black_box(back);
    });
    report.push_num("bulk_dbl_binary_ns_per_elem", st.mean_s * 1e9 / n_elems as f64);

    // Zero-copy handoff: what multicore/sequential do per chunk — wrap
    // the frozen storage in shared windows, no encode, no clone.
    let frozen = Arc::new(vec![payload.clone()]);
    let st = bh::bench("wire", "zero_copy_handoff", 1, iters.max(3), || {
        for _ in 0..64 {
            let slice = WireSlice::shared(frozen.clone(), 0, 1);
            std::hint::black_box(slice.len());
        }
    });
    report.push_num("bulk_dbl_zero_copy_ns_per_elem", st.mean_s * 1e9 / 64.0 / n_elems as f64);

    // -----------------------------------------------------------------
    // Arm 2: the multisession protocol stream (context + 48 chunks +
    // 48 outcomes) — where envelopes and field names dominate JSON.
    // -----------------------------------------------------------------
    let (tasks, outcomes, ctx) = protocol_stream();
    let mut json_total = 0usize;
    let mut bin_total = 0usize;
    json_total += futurize::wire::to_string(&ctx).unwrap().len();
    bin_total += bin::to_bytes(&ctx).unwrap().len();
    for t in &tasks {
        json_total += futurize::wire::to_string(t).unwrap().len();
        bin_total += bin::to_bytes(t).unwrap().len();
    }
    for o in &outcomes {
        json_total += futurize::wire::to_string(o).unwrap().len();
        bin_total += bin::to_bytes(o).unwrap().len();
    }
    bh::table_header(
        "multisession protocol stream (context + 48 chunks + 48 outcomes)",
        &["codec", "bytes/map-call"],
    );
    bh::table_row(&["json".into(), format!("{json_total}")]);
    bh::table_row(&["binary".into(), format!("{bin_total}")]);
    let shrink = json_total as f64 / bin_total as f64;
    println!("\nbinary shrink over JSON on the protocol stream: {shrink:.2}x (target ≥ 3x)");
    report.push_num("stream_json_bytes", json_total as f64);
    report.push_num("stream_binary_bytes", bin_total as f64);
    report.push_num("stream_shrink_vs_json", shrink);

    // -----------------------------------------------------------------
    // Arm 3: end-to-end wire bytes per map call, per backend family.
    // -----------------------------------------------------------------
    let sessions: &[(&str, &str)] = &[
        ("multicore (zero-copy)", "plan(multicore, workers = 2)"),
        ("multisession (binary frames)", "plan(multisession, workers = 2)"),
    ];
    bh::table_header(
        "physical wire bytes per map call (24 chunks over a 5k-int global)",
        &["backend", "bytes/call"],
    );
    for (label, plan) in sessions {
        let mut s = futurize::coordinator::Session::new();
        s.eval_str(plan).unwrap();
        s.eval_str("big <- 1:5000\nf <- function(x) x + length(big) * 0").unwrap();
        s.eval_str("invisible(lapply(1:2, f) |> futurize())").unwrap(); // warm pool
        futurize::wire::stats::reset();
        s.eval_str("invisible(lapply(1:24, f) |> futurize(scheduling = Inf))").unwrap();
        let bytes = futurize::wire::stats::bytes();
        bh::table_row(&[label.to_string(), format!("{bytes}")]);
        let key = if label.starts_with("multicore") {
            "e2e_multicore_bytes"
        } else {
            "e2e_multisession_bytes"
        };
        report.push_num(key, bytes as f64);
    }

    report.write().unwrap();
}
