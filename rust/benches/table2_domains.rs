//! Exp T2 — Table 2: domain-specific functions futurized, comparing
//! sequential vs futurized walltime and verifying identical results
//! where determinism applies.

use futurize::bench_harness as bh;
use futurize::prelude::*;

struct Case {
    label: &'static str,
    setup: &'static str,
    body: &'static str,
    futurized: &'static str,
    check: &'static str,
}

const CASES: &[Case] = &[
    Case {
        label: "boot::boot (R = 200)",
        setup: "data(bigcity)\nratio <- function(d, w) hlo_boot_stat(d$x, d$u, w)",
        body: "b <- boot(bigcity, statistic = ratio, R = 200, stype = \"w\") |> futurize()",
        futurized: "b <- boot(bigcity, statistic = ratio, R = 200, stype = \"w\") |> futurize()",
        check: "round(mean(b$t), 6)",
    },
    Case {
        label: "glmnet::cv.glmnet (n=400, p=20)",
        setup: "set.seed(5)\nx <- matrix(rnorm(400 * 20), nrow = 400, ncol = 20)\ny <- rnorm(400)",
        body: "cv <- cv.glmnet(x, y, nfolds = 5, nlambda = 10)",
        futurized: "cv <- cv.glmnet(x, y, nfolds = 5, nlambda = 10) |> futurize()",
        check: "round(min(cv$cvm), 6)",
    },
    Case {
        label: "lme4::allFit (7 optimizers)",
        setup: "set.seed(6)\nn <- 120\ng <- rep(letters[1:4], each = 30)\nxv <- rnorm(n)\nyv <- 1 + 2 * xv + rnorm(n)\ndf <- data.frame(y = yv, x = xv, g = g)\nm <- lmer(y ~ x + (1 | g), data = df)",
        body: "fits <- allFit(m)",
        futurized: "fits <- allFit(m) |> futurize()",
        check: "round(min(sapply(fits, function(f) f$deviance)), 4)",
    },
    Case {
        label: "caret::train (knn, 8-fold cv)",
        setup: "data(iris)\nctrl <- trainControl(method = \"cv\", number = 8)",
        body: "mod <- train(Species ~ ., data = iris, method = \"knn\", trControl = ctrl)",
        futurized: "mod <- train(Species ~ ., data = iris, method = \"knn\", trControl = ctrl) |> futurize()",
        check: "round(mod$bestAccuracy, 4)",
    },
    Case {
        label: "mgcv::bam (n=2000, PJRT gram)",
        setup: "set.seed(7)\nn <- 2000\nxv <- runif(n, 0, 10)\nyv <- sin(xv) + rnorm(n, sd = 0.1)\ndf <- data.frame(y = yv, x = xv)",
        body: "m <- bam(y ~ s(x), data = df, sp = 0.5)",
        futurized: "m <- bam(y ~ s(x), data = df, sp = 0.5) |> futurize()",
        check: "round(m$rmse, 6)",
    },
    Case {
        label: "tm::tm_map + TermDocumentMatrix",
        setup: "data(crude)\ncorpus <- Corpus(VectorSource(rep(crude, 10)))",
        body: "clean <- tm_map(corpus, tolower)\ntdm <- TermDocumentMatrix(clean)",
        futurized: "clean <- tm_map(corpus, tolower) |> futurize()\ntdm <- TermDocumentMatrix(clean)",
        check: "length(tdm$terms)",
    },
];

fn main() {
    futurize::backend::worker::maybe_worker();

    bh::table_header(
        "Table 2 domains: sequential vs futurized (multicore, 3 workers)",
        &["function", "seq", "futurized", "speedup", "check seq", "check fut"],
    );
    for c in CASES {
        // Sequential.
        let mut s1 = Session::new();
        s1.eval_str("futureSeed(11)").unwrap();
        s1.eval_str(c.setup).unwrap_or_else(|e| panic!("{}: {e}", c.label));
        // For the boot case, "sequential" still needs seed=TRUE semantics
        // for comparability; run the futurized form on plan(sequential).
        let t0 = std::time::Instant::now();
        s1.eval_str(c.body).unwrap_or_else(|e| panic!("{} seq: {e}", c.label));
        let seq_t = t0.elapsed().as_secs_f64();
        let seq_check = s1.eval_str(c.check).unwrap();

        // Futurized on 3 workers.
        let mut s2 = Session::new();
        s2.eval_str("plan(multicore, workers = 3)").unwrap();
        s2.eval_str("futureSeed(11)").unwrap();
        s2.eval_str(c.setup).unwrap();
        let t0 = std::time::Instant::now();
        s2.eval_str(c.futurized).unwrap_or_else(|e| panic!("{} fut: {e}", c.label));
        let fut_t = t0.elapsed().as_secs_f64();
        let fut_check = s2.eval_str(c.check).unwrap();

        bh::table_row(&[
            c.label.to_string(),
            format!("{:.3}s", seq_t),
            format!("{:.3}s", fut_t),
            format!("{:.2}x", seq_t / fut_t),
            format!("{seq_check}"),
            format!("{fut_check}"),
        ]);
        assert_eq!(seq_check, fut_check, "{}: futurized result diverged", c.label);
    }
    println!("\nall Table-2 domain results identical under futurization");
}
