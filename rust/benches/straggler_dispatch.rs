//! Exp X4 — static vs. adaptive dispatch on an imbalanced workload.
//!
//! Table 1 (walltime, multicore): 32 elements on 4 workers; element 1
//! costs 8 units, the rest 1 unit (the "one slow element" straggler
//! case). Three arms:
//!
//! - `scheduling = 1` — the default static policy: the straggler chunk
//!   also drags ⌈n/w⌉−1 cheap elements behind the slow one (~15 units
//!   of wall).
//! - `scheduling = Inf` — per-element chunks: best static balance
//!   (~10–11 units) but n messages per call.
//! - `scheduling = "adaptive"` — guided chunks via the streaming
//!   dispatch core: straggler lands in a small early chunk (~11 units)
//!   at a fraction of the messages.
//!
//! Table 2 (wire bytes, multisession — the only plan here that actually
//! serializes): per-element static chunking embeds every payload in
//! every message, while the shared-context protocol ships the
//! function/globals once per worker, so serialized volume drops from
//! O(chunks × payload) to O(workers × payload). Measured via the
//! wire-layer byte counter.

use futurize::bench_harness as bh;
use futurize::prelude::*;

const UNIT: f64 = 0.02;

fn timed_arm(label: &str, opts: &str) -> f64 {
    let mut session = Session::with_config(SessionConfig { time_scale: UNIT });
    session.eval_str("plan(multicore, workers = 4)").unwrap();
    session
        .eval_str("f <- function(x) { Sys.sleep(if (x == 1) 8 else 1)\nx }")
        .unwrap();
    session.eval_str("invisible(lapply(1:4, function(x) x) |> futurize())").unwrap(); // warm pool
    let st = bh::bench("straggler", label, 0, 3, || {
        session
            .eval_str(&format!("ys <- lapply(1:32, f) |> futurize({opts})"))
            .unwrap();
    });
    st.mean_s
}

fn bytes_arm(opts: &str) -> u64 {
    let mut session = Session::new();
    session.eval_str("plan(multisession, workers = 2)").unwrap();
    // A closure over a sizeable global — the payload the shared-context
    // protocol stops copying into every chunk.
    session.eval_str("big <- 1:10000\nf <- function(x) x + length(big) * 0").unwrap();
    session.eval_str("invisible(lapply(1:2, f) |> futurize())").unwrap(); // warm pool
    futurize::wire::stats::reset();
    session.eval_str(&format!("ys <- lapply(1:48, f) |> futurize({opts})")).unwrap();
    futurize::wire::stats::bytes()
}

fn main() {
    futurize::backend::worker::maybe_worker();

    bh::table_header(
        "straggler dispatch (32 tasks, one 8x-cost element, 4 workers, multicore)",
        &["policy", "walltime"],
    );
    let arms = [
        ("scheduling = 1 (static)", "scheduling = 1"),
        ("scheduling = Inf (per-element)", "scheduling = Inf"),
        ("adaptive (guided)", "scheduling = \"adaptive\""),
    ];
    let mut results = Vec::new();
    for (label, opts) in arms {
        let mean_s = timed_arm(label, opts);
        bh::table_row(&[label.to_string(), format!("{mean_s:.3}s")]);
        results.push(mean_s);
    }
    println!(
        "\nadaptive speedup over static scheduling = 1: {:.2}x",
        results[0] / results[2].max(1e-9)
    );

    bh::table_header(
        "serialized bytes per map call (48 tasks, ~80kB shared payload, multisession x2)",
        &["policy", "wire bytes/call"],
    );
    for (label, opts) in [
        ("scheduling = Inf (48 chunks)", "scheduling = Inf"),
        ("adaptive (guided chunks)", "scheduling = \"adaptive\""),
        ("scheduling = 1 (2 chunks)", "scheduling = 1"),
    ] {
        let bytes = bytes_arm(opts);
        bh::table_row(&[label.to_string(), format!("{bytes}")]);
    }
    println!(
        "\nexpected shape: static pins ~15 cost-units on the straggler's worker while \
         adaptive and per-element land at ~10-11; wire bytes stay O(workers x payload) \
         for every policy because the shared context ships once per worker"
    );
}
