//! BENCH_cache: content-addressed data-plane cache (PR 9).
//!
//! Measures the number the cache exists to improve — **physical wire
//! bytes per map call** over a large captured dataset — on a real
//! `plan(multisession, workers = 2)` session:
//!
//! - call 1 ships the dataset as `CachePut` blobs (once per worker);
//! - call 2 references it by digest, so its wire volume must collapse
//!   to task/result framing — hard-asserted at ≥5× below call 1.
//!
//! Also reported (not asserted — wall-clock is noisy on shared CI):
//! the first-call overhead of digesting + blob framing versus the same
//! call with `FUTURIZE_NO_CACHE=1`, and raw FNV digest throughput.
//! Results land in `BENCH_cache.json` (`BENCH_SMOKE=1` shrinks the
//! dataset for CI).

use futurize::backend::blobstore;
use futurize::bench_harness as bh;
use futurize::prelude::*;
use futurize::rlite::serialize::{digest_val, WireVal};
use futurize::wire::stats;

const PROG: &str = "future_sapply(1:8, function(i) sum(d) + i)";

/// Two identical maps over an `n`-double captured global on a fresh
/// multisession pool: (first-call bytes, second-call bytes, first-call
/// seconds, results). Physical frame bytes tick on the writing thread
/// — the dispatch loop runs here, so the thread-local counter sees
/// every parent→worker frame of this session and nothing else.
fn measure(n: usize, cache: bool) -> (f64, f64, f64, Vec<f64>, Vec<f64>) {
    if cache {
        std::env::remove_var(blobstore::NO_CACHE_ENV);
    } else {
        std::env::set_var(blobstore::NO_CACHE_ENV, "1");
    }
    let mut s = Session::new();
    s.eval_str("plan(multisession, workers = 2)").unwrap();
    s.eval_str(&format!("d <- sin(1:{n})")).unwrap();
    stats::reset();
    let t0 = std::time::Instant::now();
    let r1 = s.eval_str(PROG).unwrap().as_dbl_vec().unwrap();
    let first_secs = t0.elapsed().as_secs_f64();
    let first_bytes = stats::bytes() as f64;
    let r2 = s.eval_str(PROG).unwrap().as_dbl_vec().unwrap();
    let second_bytes = stats::bytes() as f64 - first_bytes;
    (first_bytes, second_bytes, first_secs, r1, r2)
}

fn main() {
    futurize::backend::worker::maybe_worker();
    let smoke = bh::smoke_mode();
    let n = if smoke { 100_000 } else { 1_000_000 };
    let mut report = bh::JsonReport::new("BENCH_cache.json");
    report.push_num("dataset_doubles", n as f64);
    report.push(
        "mode",
        futurize::wire::JsonValue::String(if smoke { "smoke" } else { "full" }.into()),
    );

    let (cached_first, cached_second, cached_secs, r1, r2) = measure(n, true);
    let (plain_first, plain_second, plain_secs, p1, _) = measure(n, false);
    std::env::remove_var(blobstore::NO_CACHE_ENV);
    let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&r1), bits(&r2), "repeat cached call diverged");
    assert_eq!(bits(&r1), bits(&p1), "cached and uncached results diverge");

    // Raw digest throughput over the same dataset (the only work the
    // cache adds on an all-resident repeat call, besides ref framing).
    let w = WireVal::Dbl((0..n).map(|i| (i as f64).sin()).collect(), None);
    let t0 = std::time::Instant::now();
    let d = digest_val(&w);
    let digest_secs = t0.elapsed().as_secs_f64();
    std::hint::black_box(d);

    bh::table_header(
        "data-plane cache: 2 identical maps over an 8n-byte global, multisession workers=2",
        &["series", "call1 bytes", "call2 bytes", "call1 secs"],
    );
    bh::table_row(&[
        "cached".into(),
        format!("{cached_first:.0}"),
        format!("{cached_second:.0}"),
        format!("{cached_secs:.3}"),
    ]);
    bh::table_row(&[
        "no-cache".into(),
        format!("{plain_first:.0}"),
        format!("{plain_second:.0}"),
        format!("{plain_secs:.3}"),
    ]);
    let reduction = cached_first / cached_second.max(1.0);
    let resend_saved = plain_second / cached_second.max(1.0);
    let overhead_pct = (cached_secs - plain_secs) / plain_secs * 100.0;
    println!(
        "\nsecond-call wire reduction: {reduction:.1}x (vs re-ship: {resend_saved:.1}x); \
         first-call overhead: {overhead_pct:+.1}%; digest: {:.0} MB/s",
        (n * 8) as f64 / 1e6 / digest_secs
    );

    report.push_num("cached_first_call_bytes", cached_first);
    report.push_num("cached_second_call_bytes", cached_second);
    report.push_num("plain_first_call_bytes", plain_first);
    report.push_num("plain_second_call_bytes", plain_second);
    report.push_num("second_call_reduction", reduction);
    report.push_num("reduction_vs_reship", resend_saved);
    report.push_num("first_call_overhead_pct", overhead_pct);
    report.push_num("digest_mb_per_sec", (n * 8) as f64 / 1e6 / digest_secs);
    report.write().unwrap();

    // The tentpole number: a second identical map must ride the ledger,
    // shipping digests instead of the dataset.
    assert!(
        cached_second * 5.0 <= cached_first,
        "second identical map must ship >=5x fewer wire bytes: \
         call1 {cached_first} vs call2 {cached_second}"
    );
    assert!(
        cached_second * 5.0 <= plain_second,
        "cached repeat call must ship >=5x fewer bytes than an uncached one: \
         {cached_second} vs {plain_second}"
    );
}
