//! Exp X1 — ablation of the unified `chunk_size`/`scheduling` options
//! (§2.4): sweep chunk granularity on a low-latency backend (multicore)
//! and a high-latency one (batchtools-sim). The crossover the options
//! exist for: fine chunks balance load when dispatch is cheap; coarse
//! chunks amortize submission cost when dispatch is expensive.

use futurize::bench_harness as bh;
use futurize::prelude::*;

const UNIT: f64 = 0.004;

fn sweep(plan: &str, label: &str) {
    bh::table_header(
        &format!("chunking sweep on {label} (48 tasks, 4 workers)"),
        &["policy", "walltime"],
    );
    for (policy, opts) in [
        ("scheduling = 1 (1 chunk/worker)", "scheduling = 1"),
        ("scheduling = 4", "scheduling = 4"),
        ("scheduling = Inf (1 elem/chunk)", "scheduling = Inf"),
        ("chunk_size = 2", "chunk_size = 2"),
        ("chunk_size = 24", "chunk_size = 24"),
    ] {
        let mut session = Session::with_config(SessionConfig { time_scale: UNIT });
        session.eval_str(&format!("plan({plan})")).unwrap();
        // Unbalanced workload: task x sleeps x/24 units, so coarse
        // contiguous chunks are skewed and benefit from fine scheduling.
        session
            .eval_str("f <- function(x) { Sys.sleep(x / 24)\nx }\nxs <- 1:48")
            .unwrap();
        session.eval_str("invisible(lapply(1:2, f) |> futurize())").unwrap(); // warm pool
        let st = bh::bench("chunking", &format!("{label}/{policy}"), 0, 3, || {
            session
                .eval_str(&format!("ys <- lapply(xs, f) |> futurize({opts})"))
                .unwrap();
        });
        bh::table_row(&[policy.to_string(), format!("{:.3}s", st.mean_s)]);
    }
}

fn main() {
    futurize::backend::worker::maybe_worker();
    sweep("multicore, workers = 4", "multicore (cheap dispatch)");
    sweep(
        "future.batchtools::batchtools_slurm, workers = 4, poll_ms = 8",
        "batchtools (8ms poll latency)",
    );
    println!(
        "\nexpected shape: fine chunks win on multicore (load balance), \
         coarse chunks win on batchtools (amortize queue latency)"
    );
}
