//! Exp T1 — Table 1 coverage: every registered map-reduce function is
//! futurized on a shared fixture; we verify identical-to-sequential
//! results and report per-call futurize overhead (transpile + dispatch).

use futurize::bench_harness as bh;
use futurize::prelude::*;

/// (label, setup, sequential expr, futurized expr)
const CASES: &[(&str, &str, &str, &str)] = &[
    ("base::lapply", "", "lapply(xs, f)", "lapply(xs, f) |> futurize()"),
    ("base::sapply", "", "sapply(xs, f)", "sapply(xs, f) |> futurize()"),
    ("base::vapply", "", "vapply(xs, f, numeric(1))", "vapply(xs, f, numeric(1)) |> futurize()"),
    ("base::mapply", "", "mapply(g2, xs, ys)", "mapply(g2, xs, ys) |> futurize()"),
    ("base::Map", "", "Map(g2, xs, ys)", "Map(g2, xs, ys) |> futurize()"),
    ("base::apply", "m <- matrix(1:24, nrow = 4)", "apply(m, 2, sum)", "apply(m, 2, sum) |> futurize()"),
    ("base::tapply", "", "tapply(vals, grp, sum)", "tapply(vals, grp, sum) |> futurize()"),
    ("base::by", "df <- data.frame(g = grp, v = vals)", "by(df, grp, function(d) sum(d$v))", "by(df, grp, function(d) sum(d$v)) |> futurize()"),
    ("base::eapply", "e <- new.env()\ne$a <- 1\ne$b <- 2", "eapply(e, f)", "eapply(e, f) |> futurize()"),
    ("base::replicate", "", "{ futureSeed(1)\nreplicate(6, rnorm(3)) |> futurize() }", "{ futureSeed(1)\nreplicate(6, rnorm(3)) |> futurize() }"),
    ("base::Filter", "", "Filter(pos, xs)", "Filter(pos, xs) |> futurize()"),
    ("base::.mapply", "", ".mapply(g2, list(xs, ys), NULL)", ".mapply(g2, list(xs, ys), NULL) |> futurize()"),
    ("stats::kernapply", "", "kernapply(vals, k3)", "kernapply(vals, k3) |> futurize()"),
    ("purrr::map", "", "map(xs, f)", "map(xs, f) |> futurize()"),
    ("purrr::map_dbl", "", "map_dbl(xs, f)", "map_dbl(xs, f) |> futurize()"),
    ("purrr::map_chr", "", "map_chr(xs, function(x) paste0(\"v\", x))", "map_chr(xs, function(x) paste0(\"v\", x)) |> futurize()"),
    ("purrr::map2", "", "map2(xs, ys, g2)", "map2(xs, ys, g2) |> futurize()"),
    ("purrr::pmap", "", "pmap(list(xs, ys), g2)", "pmap(list(xs, ys), g2) |> futurize()"),
    ("purrr::imap", "", "imap(named, function(x, nm) paste0(nm, x))", "imap(named, function(x, nm) paste0(nm, x)) |> futurize()"),
    ("purrr::modify", "", "modify(xs, f)", "modify(xs, f) |> futurize()"),
    ("purrr::map_if", "", "map_if(xs, pos, f)", "map_if(xs, pos, f) |> futurize()"),
    ("purrr::map_at", "", "map_at(xs, c(1, 2), f)", "map_at(xs, c(1, 2), f) |> futurize()"),
    ("purrr::walk", "", "walk(xs, f)", "walk(xs, f) |> futurize()"),
    ("crossmap::xmap", "", "crossmap::xmap_dbl(list(1:3, 1:2), g2)", "crossmap::xmap_dbl(list(1:3, 1:2), g2) |> futurize()"),
    ("crossmap::map_vec", "", "crossmap::map_vec(xs, f)", "crossmap::map_vec(xs, f) |> futurize()"),
    ("foreach::%do%", "", "foreach(x = xs, .combine = c) %do% { f(x) }", "foreach(x = xs, .combine = c) %do% { f(x) } |> futurize()"),
    ("foreach::times", "", "{ futureSeed(1)\ntimes(5) %do% rnorm(2) |> futurize() }", "{ futureSeed(1)\ntimes(5) %do% rnorm(2) |> futurize() }"),
    ("plyr::llply", "", "llply(xs, f)", "llply(xs, f) |> futurize()"),
    ("plyr::laply", "", "laply(xs, f)", "laply(xs, f) |> futurize()"),
    ("plyr::ldply", "", "ldply(xs, function(x) list(v = x))", "ldply(xs, function(x) list(v = x)) |> futurize()"),
    ("plyr::ddply", "df <- data.frame(g = grp, v = vals)", "ddply(df, \"g\", function(d) list(s = sum(d$v)))", "ddply(df, \"g\", function(d) list(s = sum(d$v))) |> futurize()"),
    ("plyr::mlply", "df2 <- data.frame(a = 1:3, b = 4:6)", "mlply(df2, g2)", "mlply(df2, g2) |> futurize()"),
    ("BiocParallel::bplapply", "", "bplapply(xs, f)", "bplapply(xs, f) |> futurize()"),
    ("BiocParallel::bpmapply", "", "bpmapply(g2, xs, ys)", "bpmapply(g2, xs, ys) |> futurize()"),
    ("BiocParallel::bpvec", "", "bpvec(vals, function(v) v * 2)", "bpvec(vals, function(v) v * 2) |> futurize()"),
    ("BiocParallel::bpaggregate", "", "bpaggregate(vals, grp, sum)", "bpaggregate(vals, grp, sum) |> futurize()"),
];

const FIXTURE: &str = "
f <- function(x) x^2
g2 <- function(a, b) a + b
pos <- function(x) x > 2
xs <- 1:6
ys <- 11:16
vals <- c(1, 5, 2, 8, 3, 9)
grp <- c(\"a\", \"b\", \"a\", \"b\", \"a\", \"b\")
named <- c(p = 1, q = 2)
k3 <- c(0.25, 0.5, 0.25)
";

fn main() {
    futurize::backend::worker::maybe_worker();

    bh::table_header(
        "Table 1 coverage: futurized == sequential, with per-call overhead",
        &["function", "identical", "seq", "futurized"],
    );
    let mut all_ok = true;
    for (label, setup, seq_src, fut_src) in CASES {
        let mut s1 = Session::new();
        s1.eval_str(FIXTURE).unwrap();
        if !setup.is_empty() {
            s1.eval_str(setup).unwrap();
        }
        let seq_v = s1.eval_str(seq_src).unwrap_or_else(|e| panic!("{label} seq: {e}"));

        let mut s2 = Session::new();
        s2.eval_str(FIXTURE).unwrap();
        s2.eval_str("plan(multicore, workers = 2)").unwrap();
        if !setup.is_empty() {
            s2.eval_str(setup).unwrap();
        }
        let fut_v = s2.eval_str(fut_src).unwrap_or_else(|e| panic!("{label} fut: {e}"));

        let identical = seq_v == fut_v;
        all_ok &= identical;

        let t0 = std::time::Instant::now();
        for _ in 0..20 {
            s1.eval_str(seq_src).unwrap();
        }
        let seq_t = t0.elapsed().as_secs_f64() / 20.0;
        let t0 = std::time::Instant::now();
        for _ in 0..20 {
            s2.eval_str(fut_src).unwrap();
        }
        let fut_t = t0.elapsed().as_secs_f64() / 20.0;
        bh::table_row(&[
            label.to_string(),
            if identical { "yes".into() } else { "NO".into() },
            format!("{:.0}us", seq_t * 1e6),
            format!("{:.0}us", fut_t * 1e6),
        ]);
    }
    println!(
        "\ncovered {} of the paper's Table-1 functions; identical results: {}",
        CASES.len(),
        if all_ok { "ALL" } else { "MISMATCH — see rows above" }
    );
    assert!(all_ok, "Table-1 equivalence violated");
}
