//! Exp S49 — cost of the "familiar behavior" guarantee: capturing and
//! relaying stdout + conditions from workers, vs discarding them
//! (stdout = FALSE, conditions = FALSE).

use futurize::bench_harness as bh;
use futurize::prelude::*;

fn main() {
    futurize::backend::worker::maybe_worker();

    let mut session = Session::new();
    session.eval_str("plan(multicore, workers = 2)").unwrap();
    session
        .eval_str(
            "noisy <- function(x) {\n  cat(\"out\", x, \"\\n\")\n  message(\"msg \", x)\n  x\n}\nxs <- 1:200",
        )
        .unwrap();
    session.eval_str("invisible(lapply(1:2, function(x) x) |> futurize())").unwrap();

    let relay_on = bh::bench("conditions", "relay_on_200_noisy_tasks", 1, 8, || {
        let (_, _out) = session
            .eval_captured("ys <- lapply(xs, noisy) |> futurize()");
    });
    let relay_off = bh::bench("conditions", "relay_off_200_noisy_tasks", 1, 8, || {
        let (_, _out) = session.eval_captured(
            "ys <- lapply(xs, noisy) |> futurize(stdout = FALSE, conditions = FALSE)",
        );
    });
    let quiet = bh::bench("conditions", "quiet_tasks_baseline", 1, 8, || {
        session.eval_str("ys <- lapply(xs, function(x) x) |> futurize()").unwrap();
    });

    println!(
        "\nrelay cost per noisy task: {:.1}us (on) vs {:.1}us (off); quiet baseline {:.1}us",
        relay_on.mean_s / 200.0 * 1e6,
        relay_off.mean_s / 200.0 * 1e6,
        quiet.mean_s / 200.0 * 1e6,
    );

    // Semantics check: suppression works through the relay (§4.9).
    let (_, out) = session.eval_captured(
        "ys <- lapply(1:3, function(x) { message(\"m\", x)\nx }) |> suppressMessages() |> futurize()",
    );
    assert!(!out.contains('m'), "suppressMessages must silence relayed messages");
    println!("suppressMessages() through relay: OK");
}
