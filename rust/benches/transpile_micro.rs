//! Exp T1 micro — the futurize() mechanism itself: parse, capture,
//! identify, registry lookup, rewrite, deparse. The paper's implicit
//! claim is that the transpilation layer is negligible next to any real
//! map body.

use futurize::bench_harness as bh;
use futurize::prelude::*;
use futurize::transpile::{transpile_expr, FuturizeOptions};

fn main() {
    futurize::backend::worker::maybe_worker();

    let cases = [
        ("lapply", "lapply(xs, fcn)"),
        ("purrr_map", "map(xs, fcn)"),
        ("foreach_do", "foreach(x = xs) %do% { fcn(x) }"),
        ("wrapped", "suppressMessages(local({ p <- 1\nlapply(xs, fcn) }))"),
        ("domain_boot", "boot(bigcity, statistic = ratio, R = 999, stype = \"w\")"),
    ];

    bh::table_header("futurize() transpile cost", &["call", "per-transpile"]);
    for (name, src) in cases {
        let expr = parse_expr(src).unwrap();
        let opts = FuturizeOptions::default();
        let st = bh::bench("transpile", name, 100, 10, || {
            for _ in 0..1000 {
                let out = transpile_expr(&expr, &opts).unwrap();
                std::hint::black_box(&out);
            }
        });
        bh::table_row(&[name.to_string(), format!("{:.2}us", st.mean_s / 1000.0 * 1e6)]);
    }

    // End-to-end futurize() dispatch on a trivial body (pure overhead).
    let mut session = Session::new();
    session.eval_str("xs <- 1:4\nfcn <- function(x) x").unwrap();
    let plain = bh::bench("transpile", "eval_lapply_plain", 10, 10, || {
        for _ in 0..100 {
            session.eval_str("lapply(xs, fcn)").unwrap();
        }
    });
    let fut = bh::bench("transpile", "eval_lapply_futurized_seq", 10, 10, || {
        for _ in 0..100 {
            session.eval_str("lapply(xs, fcn) |> futurize()").unwrap();
        }
    });
    println!(
        "\nfuturize() overhead on plan(sequential): {:.1}us/call (plain {:.1}us)",
        (fut.mean_s - plain.mean_s) / 100.0 * 1e6,
        plain.mean_s / 100.0 * 1e6
    );
}
