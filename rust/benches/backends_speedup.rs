//! Exp S48 — backend flexibility (§4.8): the same futurized script on
//! every plan(), reporting walltime/speedup. The paper's claim is
//! qualitative: same code, any backend; speedup shape follows worker
//! count with per-backend overhead regimes (threads < processes <
//! latency-injected cluster < polled batch queue).

use futurize::bench_harness as bh;
use futurize::prelude::*;

const UNIT: f64 = 0.01;

fn run_plan(plan: &str, label: &str, seq_mean: f64) {
    let mut session = Session::with_config(SessionConfig { time_scale: UNIT });
    session.eval_str(&format!("plan({plan})")).unwrap();
    session
        .eval_str("slow_fcn <- function(x) { Sys.sleep(1)\nx^2 }\nxs <- 1:24")
        .unwrap();
    // Warm the worker pool (plan instantiation is lazy).
    session.eval_str("invisible(lapply(1:3, slow_fcn) |> futurize())").unwrap();
    let st = bh::bench("backends", label, 0, 3, || {
        session.eval_str("ys <- lapply(xs, slow_fcn) |> futurize()").unwrap();
    });
    bh::table_row(&[
        label.to_string(),
        format!("{:.3}s", st.mean_s),
        format!("{:.2}x", seq_mean / st.mean_s),
    ]);
}

fn main() {
    futurize::backend::worker::maybe_worker();

    let mut session = Session::with_config(SessionConfig { time_scale: UNIT });
    session
        .eval_str("slow_fcn <- function(x) { Sys.sleep(1)\nx^2 }\nxs <- 1:24")
        .unwrap();
    let seq = bh::bench("backends", "sequential", 0, 3, || {
        session.eval_str("ys <- lapply(xs, slow_fcn)").unwrap();
    });

    bh::table_header(
        "Backend flexibility (24 x 1-unit tasks; §4.8)",
        &["plan()", "walltime", "speedup"],
    );
    bh::table_row(&["sequential".into(), format!("{:.3}s", seq.mean_s), "1.00x".into()]);
    run_plan("multicore, workers = 4", "multicore-4", seq.mean_s);
    run_plan("multisession, workers = 4", "multisession-4", seq.mean_s);
    run_plan(
        "future.mirai::mirai_multisession, workers = 4",
        "mirai_multisession-4",
        seq.mean_s,
    );
    run_plan(
        "cluster, workers = c(\"n1\", \"n2\", \"n3\", \"n4\"), latency_ms = 0.5",
        "cluster-4 (0.5ms links)",
        seq.mean_s,
    );
    run_plan(
        "future.batchtools::batchtools_slurm, workers = 4, poll_ms = 10",
        "batchtools-4 (10ms poll)",
        seq.mean_s,
    );
}
