//! Exp X2 — `seed = TRUE` cost: pre-allocating one L'Ecuyer-CMRG stream
//! per element (2 modular 3x3 matrix products each) vs no RNG
//! management, plus the raw stream-generation rate.

use futurize::bench_harness as bh;
use futurize::prelude::*;
use futurize::rng::make_streams;

fn main() {
    futurize::backend::worker::maybe_worker();

    // Raw stream allocation rate.
    let st = bh::bench("rng", "make_streams_10k", 2, 10, || {
        let streams = make_streams(42, 10_000);
        assert_eq!(streams.len(), 10_000);
    });
    println!(
        "per-element stream cost: {:.0}ns",
        st.mean_s / 10_000.0 * 1e9
    );

    // End-to-end: futurized map with and without seed over 1000 elements.
    let mut session = Session::new();
    session.eval_str("plan(multicore, workers = 2)").unwrap();
    session.eval_str("xs <- 1:1000\nf <- function(x) x + 1").unwrap();
    session.eval_str("invisible(lapply(xs, f) |> futurize())").unwrap();

    let no_seed = bh::bench("rng", "futurize_1000_no_seed", 1, 10, || {
        session.eval_str("ys <- lapply(xs, f) |> futurize()").unwrap();
    });
    let with_seed = bh::bench("rng", "futurize_1000_seed_true", 1, 10, || {
        session.eval_str("ys <- lapply(xs, f) |> futurize(seed = TRUE)").unwrap();
    });
    println!(
        "\nseed = TRUE overhead: {:+.1}% ({:.2}ms -> {:.2}ms)",
        (with_seed.mean_s / no_seed.mean_s - 1.0) * 100.0,
        no_seed.mean_s * 1e3,
        with_seed.mean_s * 1e3
    );

    // Reproducibility invariant (the property the cost buys).
    let draw = |workers: usize| {
        let mut s = Session::new();
        s.eval_str(&format!("plan(multicore, workers = {workers})")).unwrap();
        s.eval_str("futureSeed(7)").unwrap();
        s.eval_str("unlist(lapply(1:16, function(x) rnorm(1)) |> futurize(seed = TRUE))")
            .unwrap()
    };
    assert_eq!(draw(1), draw(4), "seed = TRUE must be worker-count invariant");
    println!("reproducibility across worker counts: OK");
}
