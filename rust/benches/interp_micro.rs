//! Exp I1 micro — per-element rlite evaluation cost in the worker map
//! loop, the hot path ISSUE 4 overhauled (COW values, interned symbols,
//! frame reuse, hoisted capture).
//!
//! Three representative map bodies are timed through the real
//! [`run_task`] slice path (what every backend executes per chunk):
//!
//! - `scalar_arith`    — `function(x) x * 2 + 1` over scalars;
//! - `vector_slice`    — `function(x) sum(x[2:9]) / 8` over 16-elem
//!   vectors (indexing + reduction);
//! - `closure_capture` — a body that defines a nested closure, which
//!   disqualifies frame reuse (exercises the escape-analysis fallback).
//!
//! Each body is measured twice: in the optimized loop and with
//! `FUTURIZE_INTERP_COMPAT=1`, which restores the pre-overhaul loop
//! *shape* (fresh iteration frame + per-element capture scope). The
//! compat numbers under-state the true merge-base cost — COW lookups,
//! interned dispatch and the scalar arithmetic fast path cannot be
//! toggled off — so `speedup_vs_compat` is a conservative lower bound
//! on the ns/elem improvement vs. the merge-base binary. Results land
//! in `BENCH_interp.json` (`BENCH_SMOKE=1` shrinks sizes for CI).
//!
//! A second series times the AOT kernel-fusion catalog (elementwise
//! polynomial, boot weighted-ratio, Gram block): each body runs once
//! interpreted and once with the recognizer's `KernelPlan` attached,
//! through the identical `run_task` slice path, landing per-shape
//! `fusion_*` entries (interp vs. kernel ns/elem and speedup) in the
//! same report.

use futurize::backend::task_runner::run_task;
use futurize::bench_harness as bh;
use futurize::future_core::{ContextBody, TaskContext, TaskKind, TaskPayload};
use futurize::rlite::env::frames_allocated;
use futurize::rlite::eval::Interp;
use futurize::rlite::serialize::{to_wire, WireVal};
use futurize::transpile::fusion;
use futurize::wire::JsonValue;

fn map_context(id: u64, f_src: &str, setup: &str) -> TaskContext {
    let mut i = Interp::new();
    if !setup.is_empty() {
        i.eval_program(setup).unwrap();
    }
    i.eval_program(&format!("__f <- {f_src}")).unwrap();
    let f = futurize::rlite::env::lookup(&i.global, "__f").unwrap();
    TaskContext {
        id,
        body: ContextBody::Map { f: to_wire(&f).unwrap(), extra: vec![] },
        globals: vec![],
        cached_globals: vec![],
        nesting: Default::default(),
        kernel: None,
        reduce: None,
    }
}

/// Same context with the fusion recognizer's plan attached — the bench
/// asserts the body actually matches so a catalog regression shows up
/// as a bench failure, not a silently-interpreted "kernel" series.
fn fused_context(id: u64, f_src: &str, setup: &str) -> TaskContext {
    let mut ctx = map_context(id, f_src, setup);
    let kernel = {
        let ContextBody::Map { f, extra } = &ctx.body else { unreachable!() };
        fusion::recognize(f, extra, &ctx.globals)
    };
    assert!(kernel.is_some(), "{f_src}: body did not match a kernel shape");
    ctx.kernel = kernel;
    ctx
}

fn slice_task(ctx: u64, items: Vec<WireVal>) -> TaskPayload {
    TaskPayload {
        id: 1,
        kind: TaskKind::MapSlice { ctx, items: items.into(), seeds: None },
        time_scale: 0.0,
        capture_stdout: true,
    }
}

struct Case {
    name: &'static str,
    f_src: &'static str,
    items: fn(usize) -> Vec<WireVal>,
}

fn scalar_items(n: usize) -> Vec<WireVal> {
    (0..n).map(|k| WireVal::Dbl(vec![k as f64], None)).collect()
}

fn vector_items(n: usize) -> Vec<WireVal> {
    (0..n)
        .map(|k| WireVal::Dbl((0..16).map(|j| (k * 16 + j) as f64).collect(), None))
        .collect()
}

const CASES: &[Case] = &[
    Case { name: "scalar_arith", f_src: "function(x) x * 2 + 1", items: scalar_items },
    Case { name: "vector_slice", f_src: "function(x) sum(x[2:9]) / 8", items: vector_items },
    Case {
        name: "closure_capture",
        f_src: "function(x) { g <- function(y) y + x\ng(x) }",
        items: scalar_items,
    },
];

/// Bodies from the fusion catalog, each timed interpreted (kernel plan
/// stripped) and fused (plan attached), through the same slice path.
struct FusedCase {
    name: &'static str,
    setup: &'static str,
    f_src: &'static str,
    items: fn(usize) -> Vec<WireVal>,
}

fn weight_items(n: usize) -> Vec<WireVal> {
    (0..n)
        .map(|k| WireVal::Dbl((0..64).map(|j| ((k + j) % 7 + 1) as f64).collect(), None))
        .collect()
}

fn gram_items(n: usize) -> Vec<WireVal> {
    (0..n)
        .map(|k| {
            let col = |c: usize| {
                WireVal::Dbl((0..8).map(|j| (k + c * 8 + j) as f64 * 0.5).collect(), None)
            };
            WireVal::List(vec![col(0), col(1)], None, None)
        })
        .collect()
}

const FUSED_CASES: &[FusedCase] = &[
    FusedCase {
        name: "poly_arith",
        setup: "",
        f_src: "function(x) 3 * x * x + 2 * x + 1",
        items: scalar_items,
    },
    FusedCase {
        name: "boot_stat",
        setup: "x <- sin(1:64)\nu <- cos(1:64) + 2",
        f_src: "function(w) sum(x * w) / sum(u * w)",
        items: weight_items,
    },
    FusedCase {
        name: "gram",
        setup: "y <- sin(1:8)",
        f_src: "function(x) hlo_gram(x, y)",
        items: gram_items,
    },
    FusedCase {
        name: "ridge",
        setup: "y <- sin(1:8)",
        f_src: "function(x) hlo_ridge(x, y, 0.5)",
        items: gram_items,
    },
];

/// ns/elem for one prepared context (compat/fusion already baked in).
fn measure_ctx(ctx: &TaskContext, items: Vec<WireVal>, n: usize, reps: usize) -> f64 {
    let task = slice_task(ctx.id, items);
    // Warmup (also forces interner/registry initialization).
    let o = run_task(&task, Some(ctx), 0, None);
    assert!(o.values.is_ok(), "ctx {}: {:?}", ctx.id, o.values);
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        let o = run_task(&task, Some(ctx), 0, None);
        std::hint::black_box(&o);
    }
    t0.elapsed().as_secs_f64() * 1e9 / (n * reps) as f64
}

/// ns/elem for one case in the current mode (compat toggled by env).
fn measure(case: &Case, n: usize, reps: usize) -> f64 {
    let ctx = map_context(1, case.f_src, "");
    let task = slice_task(1, (case.items)(n));
    // Warmup (also forces interner/registry initialization).
    let o = run_task(&task, Some(&ctx), 0, None);
    assert!(o.values.is_ok(), "{}: {:?}", case.name, o.values);
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        let o = run_task(&task, Some(&ctx), 0, None);
        std::hint::black_box(&o);
    }
    t0.elapsed().as_secs_f64() * 1e9 / (n * reps) as f64
}

fn main() {
    futurize::backend::worker::maybe_worker();
    let smoke = bh::smoke_mode();
    let (n, reps) = if smoke { (64, 4) } else { (2048, 40) };

    let mut report = bh::JsonReport::new("BENCH_interp.json");
    report.push("schema", JsonValue::String("interp_micro/v1".into()));
    report.push_num("smoke", if smoke { 1.0 } else { 0.0 });
    report.push_num("elements", n as f64);

    bh::table_header(
        "per-element map-loop eval cost",
        &["body", "ns/elem", "compat ns/elem", "speedup"],
    );
    for case in CASES {
        std::env::remove_var("FUTURIZE_INTERP_COMPAT");
        let fast = measure(case, n, reps);
        std::env::set_var("FUTURIZE_INTERP_COMPAT", "1");
        let compat = measure(case, n, reps);
        std::env::remove_var("FUTURIZE_INTERP_COMPAT");
        let speedup = compat / fast;
        bh::table_row(&[
            case.name.to_string(),
            format!("{fast:.0}"),
            format!("{compat:.0}"),
            format!("{speedup:.2}x"),
        ]);
        report.push(
            case.name,
            JsonValue::obj(vec![
                ("ns_per_elem", JsonValue::num(fast)),
                ("compat_ns_per_elem", JsonValue::num(compat)),
                ("speedup_vs_compat", JsonValue::num(speedup)),
            ]),
        );
    }

    // Kernel fusion series: each catalog body, interpreted vs. fused.
    bh::table_header(
        "kernel fusion vs interpreter",
        &["body", "interp ns/elem", "kernel ns/elem", "speedup"],
    );
    for (k, case) in FUSED_CASES.iter().enumerate() {
        let id = 10 + k as u64;
        let interp_ctx = map_context(id, case.f_src, case.setup);
        let fused_ctx = fused_context(id, case.f_src, case.setup);
        let fused_before = fusion::slices_fused();
        let interp = measure_ctx(&interp_ctx, (case.items)(n), n, reps);
        let kernel = measure_ctx(&fused_ctx, (case.items)(n), n, reps);
        assert!(
            fusion::slices_fused() > fused_before,
            "{}: fused context fell back to the interpreter",
            case.name
        );
        let speedup = interp / kernel;
        bh::table_row(&[
            case.name.to_string(),
            format!("{interp:.0}"),
            format!("{kernel:.0}"),
            format!("{speedup:.2}x"),
        ]);
        report.push(
            &format!("fusion_{}", case.name),
            JsonValue::obj(vec![
                ("interp_ns_per_elem", JsonValue::num(interp)),
                ("kernel_ns_per_elem", JsonValue::num(kernel)),
                ("speedup_vs_interp", JsonValue::num(speedup)),
            ]),
        );
    }

    // Frame allocations per element for the non-capturing body: must be
    // ~0 (the per-slice setup frames amortize to nothing).
    let ctx = map_context(2, CASES[0].f_src, "");
    let task = slice_task(2, scalar_items(n));
    let before = frames_allocated();
    let o = run_task(&task, Some(&ctx), 0, None);
    assert!(o.values.is_ok());
    let per_elem = (frames_allocated() - before) as f64 / n as f64;
    println!("\nframe allocs/elem (non-capturing body): {per_elem:.4}");
    report.push_num("frame_allocs_per_elem", per_elem);
    report.push(
        "note",
        JsonValue::String(
            "compat = FUTURIZE_INTERP_COMPAT=1 (pre-overhaul loop shape: fresh frame + \
             per-element capture); COW/interning gains are not toggleable, so speedup_vs_compat \
             is a lower bound on the improvement vs. the merge-base binary"
                .into(),
        ),
    );
    report.write().unwrap();
}
