//! BENCH_reduce: worker-side reduction fusion (ISSUE 7).
//!
//! Measures the two numbers the fusion layer exists to improve, fused
//! versus full-result path, on a real `plan(multisession, workers = 2)`
//! session:
//!
//! - **result bytes per call** — the volume of `Done` frames crossing
//!   the worker→parent process boundary (O(workers) fused, O(n) full);
//! - **ns per element** — end-to-end map-reduce wall time.
//!
//! Written to `BENCH_reduce.json` (CI smoke leg uploads it as an
//! artifact alongside BENCH_wire.json).

use futurize::bench_harness as bh;
use futurize::prelude::*;
use futurize::transpile::fusion;
use futurize::wire::stats;

/// One mode: result bytes/call and ns/elem over `reps` fused (or full)
/// `sum(future_sapply(...))` calls on a fresh multisession pool.
fn measure(n: usize, reps: usize, fuse: bool) -> (f64, f64) {
    if fuse {
        std::env::remove_var(fusion::NO_FUSION_ENV);
    } else {
        std::env::set_var(fusion::NO_FUSION_ENV, "1");
    }
    let mut s = Session::new();
    s.eval_str("plan(multisession, workers = 2)").unwrap();
    s.eval_str(&format!("xs <- 1:{n}")).unwrap();
    let prog = "sum(future_sapply(xs, function(x) x + 1, future.reduce.op = \"sum\"))";
    // Σ(x+1) for x in 1..n — integral, so both paths are exact.
    let want = (n * (n + 3)) as f64 / 2.0;
    // Warmup spawns the pool and forces registry initialization.
    assert_eq!(s.eval_str(prog).unwrap().as_f64().unwrap(), want, "fuse={fuse}");
    stats::reset();
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        let v = s.eval_str(prog).unwrap();
        std::hint::black_box(&v);
    }
    let ns_per_elem = t0.elapsed().as_secs_f64() * 1e9 / (n * reps) as f64;
    let bytes_per_call = stats::result_bytes() as f64 / reps as f64;
    (bytes_per_call, ns_per_elem)
}

fn main() {
    futurize::backend::worker::maybe_worker();
    let smoke = bh::smoke_mode();
    let (n, reps) = if smoke { (20_000, 2) } else { (100_000, 5) };
    let mut report = bh::JsonReport::new("BENCH_reduce.json");
    report.push_num("elems", n as f64);
    report.push(
        "mode",
        futurize::wire::JsonValue::String(if smoke { "smoke" } else { "full" }.into()),
    );

    let (fused_bytes, fused_ns) = measure(n, reps, true);
    let (full_bytes, full_ns) = measure(n, reps, false);
    std::env::remove_var(fusion::NO_FUSION_ENV);

    bh::table_header(
        "reduction fusion: sum over 1:n, multisession workers=2",
        &["series", "result_bytes/call", "ns/elem"],
    );
    bh::table_row(&["fused".into(), format!("{fused_bytes:.0}"), format!("{fused_ns:.1}")]);
    bh::table_row(&["full".into(), format!("{full_bytes:.0}"), format!("{full_ns:.1}")]);

    report.push_num("fused_result_bytes_per_call", fused_bytes);
    report.push_num("full_result_bytes_per_call", full_bytes);
    report.push_num("fused_ns_per_elem", fused_ns);
    report.push_num("full_ns_per_elem", full_ns);
    report.push_num("result_bytes_shrink", full_bytes / fused_bytes.max(1.0));
    report.write().unwrap();

    assert!(
        fused_bytes * 10.0 < full_bytes,
        "fused result volume must be far below the full path: {fused_bytes} vs {full_bytes}"
    );
}
