//! BENCH_cluster: the real TCP cluster backend (PR 10).
//!
//! Three series, all on localhost sockets:
//!
//! - **dispatch overhead** — wall time of a minimal trivial map on
//!   `plan(cluster_tcp, workers = 2)`, i.e. the physical per-call cost
//!   of handshake-established socket transport (connect/spawn cost is
//!   excluded by a warm-up call);
//! - **chunking sweep** — the §2.4 scheduling trade-off over a genuine
//!   socket transport, next to the same sweep on the `cluster`
//!   simulation backend, so the injected-latency model can be
//!   sanity-checked against physics;
//! - **result volume** — per-call wall time of a map returning large
//!   vectors, pinning the O(result-bytes) socket read path.
//!
//! Results land in `BENCH_cluster.json` (`BENCH_SMOKE=1` shrinks
//! iteration counts for CI). Correctness is hard-asserted
//! (bit-identical to sequential); wall-clock numbers are reported,
//! not asserted — shared CI machines are too noisy to gate on.

use futurize::bench_harness as bh;
use futurize::prelude::*;

const UNIT: f64 = 0.004;

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// The §2.4 chunking sweep (unbalanced 48-task workload, 2 workers) on
/// one plan; returns (policy label, mean seconds) per policy.
fn sweep(plan: &str, label: &str, reps: usize) -> Vec<(String, f64)> {
    bh::table_header(
        &format!("chunking sweep on {label} (48 tasks, 2 workers)"),
        &["policy", "walltime"],
    );
    let mut out = Vec::new();
    for (policy, opts) in [
        ("scheduling_1", "scheduling = 1"),
        ("scheduling_inf", "scheduling = Inf"),
        ("chunk_size_8", "chunk_size = 8"),
    ] {
        let mut session = Session::with_config(SessionConfig { time_scale: UNIT });
        session.eval_str(&format!("plan({plan})")).unwrap();
        session
            .eval_str("f <- function(x) { Sys.sleep(x / 24)\nx }\nxs <- 1:48")
            .unwrap();
        session.eval_str("invisible(lapply(1:2, f) |> futurize())").unwrap(); // warm pool
        let st = bh::bench("cluster", &format!("{label}/{policy}"), 0, reps, || {
            session
                .eval_str(&format!("ys <- lapply(xs, f) |> futurize({opts})"))
                .unwrap();
        });
        bh::table_row(&[policy.to_string(), format!("{:.3}s", st.mean_s)]);
        out.push((policy.to_string(), st.mean_s));
    }
    out
}

fn main() {
    // CRITICAL: this bench binary is its own TCP worker — the backend
    // respawns `current_exe() worker --connect <addr>`, and without
    // this guard the child would re-run the bench instead of serving.
    futurize::backend::worker::maybe_worker();

    let smoke = bh::smoke_mode();
    let reps = if smoke { 1 } else { 3 };
    let mut report = bh::JsonReport::new("BENCH_cluster.json");
    report.push(
        "mode",
        futurize::wire::JsonValue::String(if smoke { "smoke" } else { "full" }.into()),
    );

    // --- correctness pin: TCP results are bit-identical to sequential.
    let reference = Session::new()
        .eval_str("unlist(lapply(1:24, function(x) sin(x) * 2))")
        .unwrap()
        .as_dbl_vec()
        .unwrap();
    let mut s = Session::new();
    s.eval_str("plan(cluster_tcp, workers = 2)").unwrap();
    let tcp = s
        .eval_str("unlist(lapply(1:24, function(x) sin(x) * 2) |> futurize())")
        .unwrap()
        .as_dbl_vec()
        .unwrap();
    assert_eq!(bits(&reference), bits(&tcp), "TCP cluster diverged from sequential");

    // --- dispatch overhead: trivial 8-task map on a warm socket pool.
    s.eval_str("g <- function(x) x + 1").unwrap();
    s.eval_str("invisible(lapply(1:2, g) |> futurize())").unwrap();
    let st = bh::bench("cluster", "tcp/map8_trivial", 1, reps, || {
        s.eval_str("invisible(lapply(1:8, g) |> futurize(scheduling = Inf))").unwrap();
    });
    println!(
        "\ntrivial 8-task map over localhost TCP: {:.1} ms/call ({:.2} ms/task)",
        st.mean_s * 1e3,
        st.mean_s / 8.0 * 1e3
    );
    report.push_num("tcp_map8_trivial_secs", st.mean_s);
    report.push_num("tcp_per_task_ms", st.mean_s / 8.0 * 1e3);

    // --- result volume: 10k doubles back per task, O(result-bytes) read path.
    s.eval_str("h <- function(x) sin(x + 1:10000)").unwrap();
    let st = bh::bench("cluster", "tcp/map8_bulk_results", 1, reps, || {
        s.eval_str("invisible(lapply(1:8, h) |> futurize(scheduling = Inf))").unwrap();
    });
    println!(
        "8 tasks x 10k doubles back: {:.1} ms/call ({:.1} MB/s result volume)",
        st.mean_s * 1e3,
        8.0 * 10_000.0 * 8.0 / 1e6 / st.mean_s
    );
    report.push_num("tcp_bulk_results_secs", st.mean_s);
    drop(s);

    // --- chunking sweep: real sockets vs the injected-latency model.
    for (plan, label, key) in [
        ("cluster_tcp, workers = 2", "cluster_tcp (real sockets)", "tcp"),
        (
            "cluster, workers = c(\"n1\", \"n2\"), latency_ms = 0.1",
            "cluster-sim (0.1ms injected)",
            "sim",
        ),
    ] {
        for (policy, secs) in sweep(plan, label, reps) {
            report.push_num(&format!("{key}_sweep_{policy}_secs"), secs);
        }
    }

    report.write().unwrap();
    println!(
        "\nexpected shape: real-socket and simulated sweeps agree on the \
         trade-off (fine chunks balance the skewed load; localhost latency \
         is small enough that coarse chunks buy little)"
    );
}
